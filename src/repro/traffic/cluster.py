"""Fleet-of-arrays dispatch: many systolic arrays, one arrival stream.

One 128×128 array saturates quickly under open-loop load; a serving fleet
runs N of them behind a dispatcher.  This module provides the two classic
randomized-load-balancing dispatchers plus the per-array bookkeeping the
traffic simulator drives:

* :class:`JoinShortestQueue` (``"jsq"``) — route to the array with the
  fewest in-system jobs (queued + executing); optimal information, O(N)
  per decision;
* :class:`PowerOfTwoChoices` (``"p2c"``) — sample two arrays uniformly,
  route to the less loaded (Mitzenmacher's exponential-improvement
  result); O(1) information per decision, the practical choice at fleet
  scale.

:class:`ArrayNode` wraps one :class:`repro.core.scheduler.DynamicScheduler`
with admission control (``max_concurrent`` jobs co-resident on the array)
and a bounded FIFO wait queue (``queue_cap``); overflow is rejected — shed
load is an SLA miss, not a silent drop.  Nodes also expose the migration
surface `repro.traffic.rebalance` drives: queued or pristine tenants can
be taken off one node (:meth:`ArrayNode.take_for_migration`) and admitted
on another after a checkpoint-transit delay (:meth:`admit_migrated`).
"""

from __future__ import annotations

import abc
import dataclasses
import heapq
import random
from typing import Callable, Optional, Sequence

from repro.core.dnng import DNNG
from repro.core.partition import ArrayShape, Partition
from repro.core.scheduler import (
    DynamicScheduler,
    PreemptionModel,
    StageModel,
    TimeFn,
)
from repro.core.registry import Registry
from repro.traffic.arrivals import Job


class ArrayNode:
    """One systolic array in the fleet: scheduler + admission + wait queue.

    ``on_load_change`` (optional) fires after any mutation that can change
    :attr:`in_system` or the queue length — admission, queue promotion,
    completion, migration in/out — so a fleet-level load tracker
    (:class:`FleetLoads`) can maintain its heap by delta instead of
    rescanning every node per dispatch decision.
    """

    def __init__(self, index: int, array: ArrayShape, time_fn: TimeFn,
                 stage: StageModel | None, policy,
                 max_concurrent: int, queue_cap: int,
                 on_complete: Callable[["ArrayNode", str, float], None],
                 on_submit: Callable[["ArrayNode", Job, float], None]
                 | None = None,
                 keep_trace: bool = False,
                 preemption: PreemptionModel | None = None,
                 on_load_change: Callable[["ArrayNode"], None] | None = None,
                 check_invariants: bool = False, obs=None,
                 contention=None, shared_bandwidth=None):
        if max_concurrent < 1 or queue_cap < 0:
            raise ValueError(f"need max_concurrent >= 1 (got {max_concurrent})"
                             f" and queue_cap >= 0 (got {queue_cap})")
        self.index = index
        self.max_concurrent = max_concurrent
        self.queue_cap = queue_cap
        self.queue: list[Job] = []
        self.jobs: dict[str, Job] = {}   # every job on this node, by name
        self._ready_at: dict[str, float] = {}  # migrated-in transit arrivals
        self._notify_done = on_complete
        self._notify_submit = on_submit or (lambda node, job, t: None)
        self._notify_load = on_load_change or (lambda node: None)
        self._time_fn = time_fn
        self._stage = stage
        self.array = array               # current (possibly degraded) shape
        self._full = Partition(rows=array.rows, col_start=0, cols=array.cols)
        self._svc_cache: dict = {}
        # fault state (repro.chaos): `alive` is ground truth set by fault
        # injection; `health` is the HealthMonitor's *belief* — dispatch
        # acts on belief, so an undetected failure still eats jobs
        self.alive = True
        self.health = "healthy"
        self.down_since = 0.0
        self._pe_busy_carry = 0.0        # busy PE-seconds of retired schedulers
        self._stall_carry = 0.0          # bus-stall seconds of retired scheds
        self._time_scale = 1.0           # straggler compute inflation
        self._bus_scale = 1.0            # stage bus stall inflation
        self._batch_demand_scale = 1.0   # brownout batch floor shrink
        # constructor args retained so a fault can rebuild the scheduler
        self._policy = policy
        self._keep_trace = keep_trace
        self._preemption = preemption
        self._check_invariants = check_invariants
        self._obs = obs
        # memory-contention wiring: the fleet-shared bandwidth ledger (one
        # SharedBandwidth across all nodes) survives scheduler rebuilds
        self._contention = contention
        self._shared_bw = shared_bandwidth
        self.scheduler = self._new_scheduler(0.0)

    def _new_scheduler(self, start_time: float) -> DynamicScheduler:
        sched = DynamicScheduler(
            self.array, self._time_fn, stage=self._stage,
            policy=self._policy, on_complete=self._job_done,
            keep_trace=self._keep_trace, preemption=self._preemption,
            check_invariants=self._check_invariants, obs=self._obs,
            node_index=self.index, start_time=start_time,
            contention=self._contention,
            shared_bandwidth=self._shared_bw)
        sched.time_scale = self._time_scale
        sched.bus_scale = self._bus_scale
        if self._batch_demand_scale != 1.0:
            # brownout survives fault rebuilds, like the fault scales —
            # guarded so fault-free plain runs never touch the scheduler
            sched.set_batch_demand_scale(self._batch_demand_scale)
        return sched

    @property
    def in_system(self) -> int:
        """Jobs on this array: executing + waiting (the dispatch load key)."""
        return self.scheduler.n_active + len(self.queue)

    @property
    def pe_seconds_busy(self) -> float:
        """Busy PE-seconds over the node's whole life, including work done
        on schedulers retired by a fault (``0.0 + x`` is IEEE-exact, so
        the fault-free path reads the same bits as before)."""
        return self._pe_busy_carry + self.scheduler.pe_seconds_busy

    @property
    def bus_stall_s(self) -> float:
        """Memory-contention stall seconds over the node's whole life,
        including stalls booked on schedulers retired by a fault."""
        return self._stall_carry + self.scheduler.bus.stall_s

    def offer(self, job: Job) -> str:
        """Admission control at ``job.arrival``.

        Returns ``"run"`` (submitted to the array now), ``"queued"``
        (parked in the bounded FIFO), or ``"rejected"`` (queue full —
        load shed, counted as a deadline miss)."""
        if self.scheduler.n_active < self.max_concurrent:
            self.scheduler.submit(job.dnng, deadline=job.deadline,
                                  tier=job.tier)
            self.jobs[job.dnng.name] = job
            self._notify_submit(self, job, job.arrival)
            self._notify_load(self)
            return "run"
        if len(self.queue) < self.queue_cap:
            self.queue.append(job)
            self.jobs[job.dnng.name] = job
            self._notify_load(self)
            return "queued"
        return "rejected"

    def _job_done(self, tenant: str, t: float) -> None:
        self.jobs.pop(tenant, None)
        self._notify_done(self, tenant, t)
        # completion freed a co-residency slot: promote the head-of-line job
        # (a migrated-in job still in checkpoint transit is submitted with
        # its future ready instant — the scheduler holds it until then)
        while self.queue and self.scheduler.n_active < self.max_concurrent:
            job = self.queue.pop(0)
            ready = max(t, self._ready_at.pop(job.dnng.name, t))
            g = job.dnng.clone(arrival_time=ready)
            self.scheduler.submit(g, deadline=job.deadline, tier=job.tier)
            self._notify_submit(self, job, ready)
        self._notify_load(self)

    # -- migration surface (driven by repro.traffic.rebalance) --------------
    def service_estimate(self, dnng: DNNG) -> float:
        """Full-array sequential service time of one job, memoized on the
        exact layer tuple (frozen dataclasses, hashable) — the rebalancer's
        deadline-pressure oracle."""
        key = dnng.layers
        est = self._svc_cache.get(key)
        if est is None:
            est = sum(self._time_fn(layer, self._full)
                      for layer in dnng.layers)
            if self._stage is not None:
                est += sum(self._stage.stage_in_s(layer)
                           + self._stage.stage_out_s(layer)
                           for layer in dnng.layers)
            self._svc_cache[key] = est
        return est

    def wait_estimate(self) -> float:
        """Rough time before a queued job gets a run slot: the running
        jobs' remaining work (half their service, on average) plus the
        queued backlog, spread over the co-residency slots."""
        queued = {j.dnng.name for j in self.queue}
        running = sum(self.service_estimate(j.dnng)
                      for name, j in self.jobs.items() if name not in queued)
        backlog = sum(self.service_estimate(j.dnng) for j in self.queue)
        return (running / 2.0 + backlog) / self.max_concurrent

    def take_for_migration(self, name: str) -> Optional[Job]:
        """Remove a queued or pristine-submitted job for migration; None
        when the job is unknown or already has array state."""
        for i, job in enumerate(self.queue):
            if job.dnng.name == name:
                del self.queue[i]
                self._ready_at.pop(name, None)
                job = self.jobs.pop(name)
                self._notify_load(self)
                return job
        if name in self.jobs and self.scheduler.withdraw(name):
            job = self.jobs.pop(name)
            self._notify_load(self)
            return job
        return None

    def admit_migrated(self, job: Job, now: float, ready_at: float) -> str:
        """Admit a migrated-in job that becomes runnable at ``ready_at``
        (its checkpoint is in transit until then)."""
        self.jobs[job.dnng.name] = job
        if self.scheduler.n_active < self.max_concurrent:
            arrival = max(now, ready_at, self.scheduler.now)
            g = job.dnng.clone(arrival_time=arrival)
            self.scheduler.submit(g, deadline=job.deadline, tier=job.tier)
            self._notify_submit(self, job, arrival)
            self._notify_load(self)
            return "run"
        if len(self.queue) < self.queue_cap:
            self.queue.append(job)
            self._ready_at[job.dnng.name] = ready_at
            self._notify_load(self)
            return "queued"
        del self.jobs[job.dnng.name]
        raise ValueError(f"migration target {self.index} cannot accept "
                         f"{job.dnng.name!r}: queue full")

    # -- fault surface (driven by repro.chaos) ------------------------------
    def _evacuate(self) -> list[tuple[Job, int]]:
        """Pull every resident job off the node with its checkpointed
        (completed-layer) progress; running jobs first in submit order,
        then the FIFO queue.  Leaves queue/jobs/ready empty and banks the
        retired scheduler's busy PE-seconds."""
        progress = self.scheduler.progress()
        queued = {j.dnng.name for j in self.queue}
        lost = [(job, progress.get(name, 0))
                for name, job in self.jobs.items() if name not in queued]
        lost.extend((job, 0) for job in self.queue)
        self.queue.clear()
        self.jobs.clear()
        self._ready_at.clear()
        self._pe_busy_carry += self.scheduler.pe_seconds_busy
        self._stall_carry += self.scheduler.bus.stall_s
        return lost

    def fail(self, now: float) -> list[tuple[Job, int]]:
        """Kill the node at ``now``.  Every resident job is lost (returned
        as ``(job, checkpointed_layers)`` — completed layers were staged
        out to DRAM and survive; in-flight fractions do not).  The node
        gets a fresh empty scheduler so it can be repaired later."""
        lost = self._evacuate()
        self.scheduler = self._new_scheduler(now)
        self.alive = False
        self.down_since = now
        self._notify_load(self)
        return lost

    def repair(self, now: float) -> None:
        """Bring a failed node back (empty) at ``now``."""
        self.alive = True
        self.down_since = 0.0
        self._notify_load(self)

    def degrade(self, now: float, dead_cols: int) -> list[tuple[Job, int]]:
        """Lose ``dead_cols`` columns at ``now``: the array shrinks, and
        resident tenants are re-admitted onto a fresh scheduler over the
        surviving columns — the partition policy re-fits them on the next
        assignment round.  Checkpointed layers are dropped from the
        re-submitted graphs (their outputs sit in DRAM); returns any jobs
        that no longer fit (queue overflow) as ``(job, done)`` pairs."""
        if not 1 <= dead_cols < self.array.cols:
            raise ValueError(f"node {self.index} has {self.array.cols} "
                             f"columns; cannot lose {dead_cols}")
        from repro.chaos.recovery import truncate_dnng
        evacuated = self._evacuate()
        self.array = ArrayShape(rows=self.array.rows,
                                cols=self.array.cols - dead_cols)
        self._full = Partition(rows=self.array.rows, col_start=0,
                               cols=self.array.cols)
        self._svc_cache.clear()
        self.scheduler = self._new_scheduler(now)
        overflow: list[tuple[Job, int]] = []
        for job, done in evacuated:
            if done > 0:
                job = dataclasses.replace(
                    job, dnng=truncate_dnng(job.dnng, done, arrival_time=now))
            if self.scheduler.n_active < self.max_concurrent:
                self.scheduler.submit(job.dnng.clone(arrival_time=now),
                                      deadline=job.deadline, tier=job.tier)
                self.jobs[job.dnng.name] = job
                self._notify_submit(self, job, now)
            elif len(self.queue) < self.queue_cap:
                self.queue.append(job)
                self.jobs[job.dnng.name] = job
            else:
                overflow.append((job, done))
        self._notify_load(self)
        return overflow

    def set_compute_scale(self, factor: float) -> None:
        """Straggler injection: newly launched layers run ``factor``×
        slower (1.0 restores nominal speed)."""
        self._time_scale = factor
        self.scheduler.time_scale = factor

    def set_bus_scale(self, factor: float) -> None:
        """Bus-stall injection: newly acquired stage transfers take
        ``factor``× longer (1.0 restores nominal bandwidth)."""
        self._bus_scale = factor
        self.scheduler.bus_scale = factor

    def set_batch_demand_scale(self, factor: float) -> None:
        """Brownout floor shrink (`repro.overload`): batch tenants'
        column demand scales by ``factor`` (1.0 restores nominal).
        Retained so a fault-rebuilt scheduler inherits the active
        brownout stage like the fault scales."""
        self._batch_demand_scale = factor
        self.scheduler.set_batch_demand_scale(factor)


# ---------------------------------------------------------------------------
# fleet load tracking + dispatchers
# ---------------------------------------------------------------------------

class FleetLoads:
    """Delta-maintained per-node loads with a lazily-rebuilt min-heap.

    The traffic simulator used to rebuild ``[n.in_system for n in nodes]``
    on every arrival — an O(N) scan per dispatch decision that dominates
    at fleet scale (the scale bench runs 64 arrays).  Nodes push load
    changes via their ``on_load_change`` hook; the heap accumulates stale
    entries and discards them on pop (the classic lazy-deletion heap), so
    a jsq decision is O(log N) amortized and p2c is O(1).

    ``min_index()`` returns exactly ``argmin_i (loads[i], i)`` — the same
    deterministic tie-break as the linear scan it replaces.
    """

    __slots__ = ("loads", "queued", "_heap", "_queued_total",
                 "_excluded", "_n_excluded")

    def __init__(self, nodes: Sequence["ArrayNode"]):
        self.loads = [n.in_system for n in nodes]
        self.queued = [len(n.queue) for n in nodes]
        self._queued_total = sum(self.queued)
        self._heap = [(load, i) for i, load in enumerate(self.loads)]
        heapq.heapify(self._heap)
        self._excluded = [False] * len(self.loads)
        self._n_excluded = 0

    def update(self, node: "ArrayNode") -> None:
        """The node's ``on_load_change`` target."""
        i = node.index
        load = node.in_system
        q = len(node.queue)
        self._queued_total += q - self.queued[i]
        self.queued[i] = q
        if load != self.loads[i]:
            self.loads[i] = load
            heap = self._heap
            heapq.heappush(heap, (load, i))
            if len(heap) > 64 + 8 * len(self.loads):
                # compact the lazy-deletion backlog (amortized O(N))
                heap[:] = [(ld, j) for j, ld in enumerate(self.loads)]
                heapq.heapify(heap)

    @property
    def queued_total(self) -> int:
        """Fleet-wide queue depth (the per-arrival depth sample)."""
        return self._queued_total

    # -- health exclusion (driven by repro.chaos.HealthMonitor) -------------
    def exclude(self, i: int) -> None:
        """Take node ``i`` out of routing (belief: suspect or dead)."""
        if not self._excluded[i]:
            self._excluded[i] = True
            self._n_excluded += 1

    def readmit(self, i: int) -> None:
        """Return node ``i`` to routing; its heap entries were consumed
        while excluded, so push a fresh one."""
        if self._excluded[i]:
            self._excluded[i] = False
            self._n_excluded -= 1
            heapq.heappush(self._heap, (self.loads[i], i))

    @property
    def routing_loads(self) -> Sequence[float]:
        """The load view dispatchers route on: the live ``loads`` list
        itself while nothing is excluded (the common, fault-free case —
        same object, zero cost), else a copy with excluded nodes pinned
        to +inf so load-comparing dispatchers avoid them."""
        if self._n_excluded == 0:
            return self.loads
        inf = float("inf")
        return [inf if self._excluded[i] else ld
                for i, ld in enumerate(self.loads)]

    def min_index(self) -> int:
        heap = self._heap
        loads = self.loads
        if self._n_excluded == 0:
            while True:
                load, i = heap[0]
                if loads[i] == load:
                    return i
                heapq.heappop(heap)  # stale: the node's load moved on
        excluded = self._excluded
        while heap:
            load, i = heap[0]
            if not excluded[i] and loads[i] == load:
                return i
            heapq.heappop(heap)  # stale, or excluded (readmit re-pushes)
        # every node excluded: fall back to the linear argmin so routing
        # still returns a target (the dispatch then fails realistically)
        return min(range(len(loads)), key=lambda i: (loads[i], i))


class Dispatcher(abc.ABC):
    """Pick a target array for an arriving job from in-system loads."""

    name: str = ""

    @abc.abstractmethod
    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        """Index of the array to route to (``loads[i]`` = jobs in system)."""

    def choose_tracked(self, fleet: FleetLoads, rng: random.Random) -> int:
        """Like :meth:`choose`, reading a maintained :class:`FleetLoads`
        instead of a freshly scanned load list.  The default delegates to
        :meth:`choose` on the tracker's load array (correct for any
        dispatcher); jsq/p2c override with heap / O(1) reads.  Must be
        decision-identical to ``choose`` — including rng consumption.
        Routes on ``routing_loads`` so health-excluded nodes (pinned to
        +inf) lose every load comparison; with no exclusions that is the
        plain load list itself."""
        return self.choose(fleet.routing_loads, rng)


_REGISTRY = Registry("dispatcher")


def register_dispatcher(name: str):
    return _REGISTRY.register(name)


def list_dispatchers() -> list[str]:
    return _REGISTRY.names()


def resolve_dispatcher(dispatch) -> Dispatcher:
    return _REGISTRY.resolve(dispatch, Dispatcher)


@register_dispatcher("jsq")
class JoinShortestQueue(Dispatcher):
    """Full-information balancing: fewest in-system jobs, ties → lowest
    index (deterministic)."""

    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        return min(range(len(loads)), key=lambda i: (loads[i], i))

    def choose_tracked(self, fleet: FleetLoads, rng: random.Random) -> int:
        # heap argmin == linear argmin incl. the lowest-index tie-break
        return fleet.min_index()


@register_dispatcher("p2c")
class PowerOfTwoChoices(Dispatcher):
    """Sample two distinct arrays, keep the shorter queue (Mitzenmacher
    1996); collapses to the single array when the fleet has one."""

    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        if len(loads) == 1:
            return 0
        i, j = rng.sample(range(len(loads)), 2)
        if loads[j] < loads[i] or (loads[j] == loads[i] and j < i):
            return j
        return i
    # choose_tracked: the base delegation is already O(1) per decision —
    # choose() only indexes the two sampled loads


@register_dispatcher("rr")
class RoundRobin(Dispatcher):
    """Load-oblivious cyclic dispatch: arrival ``k`` goes to array
    ``k mod N``.  Deliberately ignores both ``loads`` and ``rng`` — the
    decision depends on nothing but the arrival index, which is what makes
    it the sharded simulator's *exact-identity* routing mode
    (`repro.traffic.sharded`): every pod derives the same decision with no
    load exchange, so a sharded run reproduces the single-process run
    byte-for-byte."""

    def __init__(self):
        self._next = 0

    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        i = self._next % len(loads)
        self._next = i + 1
        return i
