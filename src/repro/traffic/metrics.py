"""SLA metrics for open-loop serving: percentiles, misses, goodput, depth.

A finished simulation is a list of :class:`JobRecord` — one per *arrived*
job, whether it was rejected at admission, still in flight at the end, or
completed.  :func:`summarize` folds them into a :class:`TrafficMetrics`
(with per-tenant and per-tier splits), the numbers BENCH_traffic.json and
``Session.serve`` report:

* **latency** — completion − arrival (queueing + service), p50/p95/p99 by
  linear interpolation over the completed set;
* **deadline-miss rate** — fraction of arrived jobs that were rejected,
  never completed, or completed after their deadline (rejects *are*
  misses: open-loop load does not go away because we shed it);
* **goodput** — deadline-met completions per second of simulated time;
* **queue depth** — mean/max of the dispatcher queue sampled at every
  arrival (the paper's A_t instants);
* **utilization** — time-weighted compute-busy PE fraction over the fleet.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class JobRecord:
    """Lifecycle of one arrived job (rejected jobs have ``submitted=None``)."""

    job_id: int
    model: str
    tier: int
    arrival: float
    deadline: float
    array: Optional[int] = None      # dispatch target (cluster runs)
    submitted: Optional[float] = None  # admission instant; None = rejected
    completed: Optional[float] = None

    @property
    def rejected(self) -> bool:
        return self.submitted is None

    @property
    def latency(self) -> Optional[float]:
        if self.completed is None:
            return None
        return self.completed - self.arrival

    @property
    def met_deadline(self) -> bool:
        return self.completed is not None and self.completed <= self.deadline


def percentile(values: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile (numpy's default), pure Python so the
    metrics stay dependency-free and bit-stable across platforms."""
    if not values:
        return float("nan")
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile {p} out of [0, 100]")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    rank = p / 100.0 * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass(frozen=True)
class TrafficMetrics:
    """Aggregate SLA metrics over one simulated serve run."""

    jobs_arrived: int
    jobs_rejected: int
    jobs_completed: int
    deadline_misses: int
    p50_latency_s: float
    p95_latency_s: float
    p99_latency_s: float
    mean_latency_s: float
    goodput_jobs_per_s: float
    queue_depth_mean: float
    queue_depth_max: int
    utilization: float
    duration_s: float
    # runtime-adaptation counters (0 unless preemption / migration enabled);
    # kept out of as_dict() so pre-existing bench records stay byte-stable —
    # ServeResult.as_dict() appends them when the features are armed
    preemptions: int = 0
    migrations: int = 0
    # fairness accounting (None unless the run armed it — see
    # TrafficSimulator's ``fairness=`` flag); the as_dict() keys appear only
    # when set, so pre-fairness records regenerate byte-identically.  The
    # slowdown gate and the dominant-share gate are independent: the
    # sharded simulator computes slowdowns from merged records but cannot
    # sample a global in-flight share series.
    jain_fairness: Optional[float] = None
    per_tenant_slowdown: Optional[dict] = None
    jain_dominant_share: Optional[float] = None
    dominant_share_mean: Optional[dict] = None
    # fault/recovery accounting (None unless the run armed ``faults=`` —
    # see repro.chaos); same append-only as_dict contract as fairness
    faults_injected: Optional[int] = None
    jobs_lost: Optional[int] = None
    jobs_retried: Optional[int] = None
    jobs_recovered: Optional[int] = None
    retries_exhausted: Optional[int] = None
    jobs_shed: Optional[int] = None
    availability_by_tier: Optional[dict] = None
    # memory-contention accounting (None unless the run armed ``memory=`` —
    # see repro.core.scheduler.MemorySystem); appended after the chaos gates
    memory_stall_s: Optional[float] = None
    memory_stall_by_node: Optional[dict] = None
    memory_peak_pressure: Optional[float] = None
    # overload-control accounting (None unless the run armed admission/
    # brownout — see repro.overload); appended after the memory gates
    rejections_by_cause: Optional[dict] = None
    shed_by_tier: Optional[dict] = None
    brownout_transitions: Optional[int] = None
    brownout_energy_j: Optional[float] = None

    @property
    def deadline_miss_rate(self) -> float:
        return (self.deadline_misses / self.jobs_arrived
                if self.jobs_arrived else 0.0)

    @property
    def rejection_rate(self) -> float:
        return (self.jobs_rejected / self.jobs_arrived
                if self.jobs_arrived else 0.0)

    def as_dict(self) -> dict:
        out = {
            "jobs_arrived": self.jobs_arrived,
            "jobs_rejected": self.jobs_rejected,
            "jobs_completed": self.jobs_completed,
            "deadline_miss_rate": self.deadline_miss_rate,
            "rejection_rate": self.rejection_rate,
            "p50_latency_s": self.p50_latency_s,
            "p95_latency_s": self.p95_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "mean_latency_s": self.mean_latency_s,
            "goodput_jobs_per_s": self.goodput_jobs_per_s,
            "queue_depth_mean": self.queue_depth_mean,
            "queue_depth_max": self.queue_depth_max,
            "utilization": self.utilization,
            "duration_s": self.duration_s,
        }
        # fairness keys append AFTER the stable prefix, in a fixed order,
        # only when the accounting ran (byte-stability contract — see
        # tests/test_record_stability.py)
        if self.jain_fairness is not None:
            out["jain_fairness"] = self.jain_fairness
            out["per_tenant_slowdown"] = dict(
                sorted((self.per_tenant_slowdown or {}).items()))
        if self.jain_dominant_share is not None:
            out["jain_dominant_share"] = self.jain_dominant_share
            out["dominant_share_mean"] = dict(
                sorted((self.dominant_share_mean or {}).items()))
        # chaos keys: appended only when fault injection was armed
        if self.faults_injected is not None:
            out["faults_injected"] = self.faults_injected
            out["jobs_lost"] = self.jobs_lost
            out["jobs_retried"] = self.jobs_retried
            out["jobs_recovered"] = self.jobs_recovered
            out["retries_exhausted"] = self.retries_exhausted
            out["jobs_shed"] = self.jobs_shed
            out["availability_by_tier"] = dict(
                sorted((self.availability_by_tier or {}).items()))
        # memory keys: appended only when the contention model was armed,
        # AFTER the chaos gates (append-only byte-stability contract)
        if self.memory_stall_s is not None:
            out["memory_stall_s"] = self.memory_stall_s
            out["memory_stall_by_node"] = dict(
                sorted((self.memory_stall_by_node or {}).items()))
            out["memory_peak_pressure"] = self.memory_peak_pressure
        # overload keys: appended only when admission/brownout was armed,
        # AFTER the memory gates (append-only byte-stability contract)
        if self.rejections_by_cause is not None:
            out["rejections_by_cause"] = {
                k: (self.rejections_by_cause or {}).get(k, 0)
                for k in ("queue_full", "admission_shed", "recovery_shed")}
            out["shed_by_tier"] = dict(
                sorted((self.shed_by_tier or {}).items()))
            out["brownout_transitions"] = self.brownout_transitions
            out["brownout_energy_j"] = self.brownout_energy_j
        return out


def summarize(records: Sequence[JobRecord], duration_s: float,
              pe_seconds_busy: float = 0.0, total_pes: int = 0,
              queue_depth_samples: Sequence[int] = (),
              preemptions: int = 0, migrations: int = 0,
              fairness=None, chaos=None, memory=None,
              overload=None) -> TrafficMetrics:
    """Fold job records into :class:`TrafficMetrics`.

    ``pe_seconds_busy``/``total_pes`` feed the time-weighted utilization
    (busy PE-seconds over ``duration_s × total_pes``); ``queue_depth_samples``
    are dispatcher-queue depths observed at each arrival instant;
    ``preemptions``/``migrations`` are the runtime-adaptation counters
    accumulated by the scheduler and rebalancer.

    ``fairness`` (optional, duck-typed so this module stays free of a
    `repro.fairness` dependency) is a
    :class:`~repro.fairness.accounting.FairnessReport`-shaped object; its
    numbers populate the gated fairness fields.

    ``chaos`` (optional, duck-typed likewise) is a
    :class:`~repro.chaos.controller.ChaosController`-shaped object; its
    counters populate the gated fault/recovery fields, and per-tier
    availability (completed / arrived) is computed from the records.

    ``memory`` (optional, duck-typed likewise) carries the contention
    accounting of an armed memory model: ``stall_s`` (fleet total extra
    bus seconds), ``stall_by_node`` (node index → stall seconds) and
    ``peak_pressure`` (max per-window demand over capacity); they populate
    the gated memory fields.

    ``overload`` (optional, duck-typed likewise) carries the overload-
    control accounting of an armed admission policy / brownout controller:
    ``rejections_by_cause`` (cause name → count), ``shed_by_tier`` (tier →
    non-admitted count), ``brownout_transitions`` and
    ``brownout_energy_j``; they populate the gated overload fields.
    """
    lats = [r.latency for r in records if r.latency is not None]
    completed = [r for r in records if r.completed is not None]
    met = sum(1 for r in completed if r.met_deadline)
    misses = sum(1 for r in records if not r.met_deadline)
    cap = duration_s * total_pes
    availability = None
    if chaos is not None:
        arrived_by_tier: dict = {}
        done_by_tier: dict = {}
        for r in records:
            arrived_by_tier[r.tier] = arrived_by_tier.get(r.tier, 0) + 1
            if r.completed is not None:
                done_by_tier[r.tier] = done_by_tier.get(r.tier, 0) + 1
        availability = {t: done_by_tier.get(t, 0) / n
                        for t, n in arrived_by_tier.items()}
    return TrafficMetrics(
        jobs_arrived=len(records),
        jobs_rejected=sum(1 for r in records if r.rejected),
        jobs_completed=len(completed),
        deadline_misses=misses,
        p50_latency_s=percentile(lats, 50.0),
        p95_latency_s=percentile(lats, 95.0),
        p99_latency_s=percentile(lats, 99.0),
        mean_latency_s=sum(lats) / len(lats) if lats else float("nan"),
        goodput_jobs_per_s=met / duration_s if duration_s > 0 else 0.0,
        queue_depth_mean=(sum(queue_depth_samples) / len(queue_depth_samples)
                          if queue_depth_samples else 0.0),
        queue_depth_max=max(queue_depth_samples, default=0),
        utilization=pe_seconds_busy / cap if cap > 0 else 0.0,
        duration_s=duration_s,
        preemptions=preemptions,
        migrations=migrations,
        jain_fairness=(fairness.jain_fairness
                       if fairness is not None else None),
        per_tenant_slowdown=(dict(fairness.per_tenant_slowdown)
                             if fairness is not None else None),
        jain_dominant_share=(fairness.jain_dominant_share
                             if fairness is not None else None),
        dominant_share_mean=(
            dict(fairness.dominant_share_mean)
            if fairness is not None and fairness.dominant_share_mean
            is not None else None),
        faults_injected=(chaos.faults_injected
                         if chaos is not None else None),
        jobs_lost=chaos.jobs_lost if chaos is not None else None,
        jobs_retried=chaos.jobs_retried if chaos is not None else None,
        jobs_recovered=(chaos.jobs_recovered
                        if chaos is not None else None),
        retries_exhausted=(chaos.retries_exhausted
                           if chaos is not None else None),
        jobs_shed=chaos.jobs_shed if chaos is not None else None,
        availability_by_tier=availability,
        memory_stall_s=memory.stall_s if memory is not None else None,
        memory_stall_by_node=(dict(memory.stall_by_node)
                              if memory is not None else None),
        memory_peak_pressure=(memory.peak_pressure
                              if memory is not None else None),
        rejections_by_cause=(dict(overload.rejections_by_cause)
                             if overload is not None else None),
        shed_by_tier=(dict(overload.shed_by_tier)
                      if overload is not None else None),
        brownout_transitions=(overload.brownout_transitions
                              if overload is not None else None),
        brownout_energy_j=(overload.brownout_energy_j
                           if overload is not None else None),
    )


def split_by(records: Sequence[JobRecord], key: str) -> dict:
    """Group records by a JobRecord attribute (``"model"``, ``"tier"``,
    ``"array"``) — the per-tenant / per-SLA-class views."""
    out: dict = {}
    for r in records:
        out.setdefault(getattr(r, key), []).append(r)
    return out
