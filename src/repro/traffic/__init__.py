"""`repro.traffic` — open-loop arrival-driven serving simulation.

The closed-workload harness measures makespan on a fixed batch; this
package measures what a serving system is actually judged on: latency
percentiles, deadline-miss rate and goodput under a live arrival process,
with the partition policy re-running on every arrival and completion.

    from repro.traffic import PoissonArrivals, TrafficSimulator

    arr = PoissonArrivals(rate=2000.0, horizon=0.05, seed=0, pool="light")
    res = TrafficSimulator(arr, policy="proportional").run()
    print(res.metrics.p99_latency_s, res.metrics.deadline_miss_rate)

``arrivals``  — seeded Poisson / MMPP / diurnal / trace-replay job streams
plus ``batch_instance`` production-trace (Alibaba-style CSV) replay.
``simulator`` — the discrete-event loop + admission control + ServeResult.
``sharded``   — pod-per-process fleet simulation, epoch-synced dispatch.
``metrics``   — p50/p95/p99, miss rate, goodput, queue depth, utilization.
``cluster``   — N-array fleets with jsq / p2c / round-robin dispatch.
``rebalance`` — cross-node tenant migration under a checkpoint-cost model.
"""

from repro.traffic.arrivals import (
    ArrivalProcess,
    BatchInstanceArrivals,
    DiurnalArrivals,
    Job,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    get_arrival_process,
    list_arrival_processes,
    register_arrivals,
    resolve_arrivals,
    synth_batch_instance_rows,
)
from repro.traffic.cluster import (
    ArrayNode,
    Dispatcher,
    JoinShortestQueue,
    PowerOfTwoChoices,
    RoundRobin,
    list_dispatchers,
    register_dispatcher,
    resolve_dispatcher,
)
from repro.traffic.metrics import (
    JobRecord,
    TrafficMetrics,
    percentile,
    split_by,
    summarize,
)
from repro.traffic.rebalance import (
    MigrateOnPressure,
    MigrationModel,
    Rebalancer,
    list_rebalancers,
    register_rebalancer,
    resolve_rebalancer,
)
from repro.traffic.sharded import (
    PodFailureError,
    ShardedTrafficSimulator,
    serve_sharded,
)
from repro.traffic.simulator import ServeResult, TrafficSimulator, serve

__all__ = [
    # arrivals
    "Job", "ArrivalProcess",
    "PoissonArrivals", "MMPPArrivals", "DiurnalArrivals", "TraceArrivals",
    "BatchInstanceArrivals", "synth_batch_instance_rows",
    "register_arrivals", "get_arrival_process", "list_arrival_processes",
    "resolve_arrivals",
    # cluster
    "ArrayNode", "Dispatcher", "JoinShortestQueue", "PowerOfTwoChoices",
    "RoundRobin",
    "register_dispatcher", "list_dispatchers", "resolve_dispatcher",
    # metrics
    "JobRecord", "TrafficMetrics", "percentile", "summarize", "split_by",
    # rebalance
    "Rebalancer", "MigrationModel", "MigrateOnPressure",
    "register_rebalancer", "list_rebalancers", "resolve_rebalancer",
    # simulator
    "TrafficSimulator", "ServeResult", "serve",
    "ShardedTrafficSimulator", "serve_sharded", "PodFailureError",
]
