"""Open-loop arrival-driven simulation over partitioned systolic arrays.

The closed-workload harness (:func:`repro.core.scheduler.schedule_dynamic`)
answers "how fast does this fixed batch drain?".  :class:`TrafficSimulator`
answers the serving question: under a live arrival process, what latency
percentiles, deadline-miss rate and goodput does a partition policy
deliver?  It is the substrate every registered policy plugs into unchanged:

* arrivals come from a `repro.traffic.arrivals` process (Poisson / MMPP /
  diurnal / trace replay), each job one Table-1 DNNG with a deadline;
* a dispatcher (`repro.traffic.cluster`) routes each job to one of
  ``n_arrays`` systolic arrays; each array runs its own incremental
  :class:`~repro.core.scheduler.DynamicScheduler`, so the policy's
  split+assign re-runs on **every** arrival and completion — the paper's
  §3.3 dynamic re-partitioning under open load, not a one-shot split;
* admission control bounds co-residency (``max_concurrent``) and the wait
  queue (``queue_cap``); overflow is shed and counted as an SLA miss;
* results fold into `repro.traffic.metrics` SLA numbers.

Everything is deterministic under a fixed seed: the arrival stream owns its
rng, the dispatcher gets a derived ``random.Random(seed)``, and the
scheduler itself is event-ordered with a stable tie-break.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.core.scheduler import ScheduleResult
from repro.traffic.arrivals import ArrivalProcess, Job, resolve_arrivals
from repro.traffic.cluster import ArrayNode, FleetLoads, resolve_dispatcher
from repro.traffic.metrics import (
    JobRecord,
    TrafficMetrics,
    split_by,
    summarize,
)


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One open-loop serve run: per-job records + aggregate SLA metrics."""

    policy: str
    backend: str
    arrivals: str
    dispatch: str
    n_arrays: int
    records: tuple[JobRecord, ...]
    metrics: TrafficMetrics
    schedules: Optional[tuple[ScheduleResult, ...]] = None
    preemption: Optional[str] = None   # PreemptionModel summary, None = off
    rebalance: Optional[str] = None    # rebalancer name, None = off
    # FairnessReport (repro.fairness.accounting) when the run armed
    # fairness accounting; its headline numbers also live in the gated
    # metrics fields — this keeps the raw dominant-share series
    fairness: Optional[object] = None
    # repro.obs.Timeline when the run armed observability (obs=):
    # the collected tracer + time-series registry, with exporter
    # shortcuts (render / chrome trace / CSV); None = obs disabled
    timeline: Optional[object] = None
    # fault injection (repro.chaos) when the run armed faults=: plan and
    # recovery-policy names plus the full ChaosReport (counters + belief
    # transitions); all None on fault-free runs
    faults: Optional[str] = None
    recovery: Optional[str] = None
    chaos: Optional[object] = None
    # memory-contention model descriptor (repr of the armed
    # ContentionModel) when the run armed memory=; None = off — the
    # stall/pressure numbers live in the gated metrics fields
    memory: Optional[str] = None
    # overload-control descriptor ("admission=<name>", "brownout", or
    # both joined with "+") when the run armed repro.overload knobs;
    # None = off — shed counts live in the gated metrics fields
    overload: Optional[str] = None
    # BrownoutReport (stage ladder + transition log) when brownout was
    # armed; carried on the result object, not serialized
    brownout: Optional[object] = None

    def per(self, key: str) -> dict:
        """Split metrics by ``"model"``, ``"tier"`` or ``"array"`` — the
        per-tenant / per-SLA-class / per-node views.  Group metrics carry
        latency + miss numbers; fleet-level utilization and queue depth are
        only meaningful in the aggregate and read 0 here."""
        return {k: summarize(rs, self.metrics.duration_s)
                for k, rs in sorted(split_by(self.records, key).items(),
                                    key=lambda kv: str(kv[0]))}

    def per_class_p99_delta(self, baseline: "ServeResult") -> dict:
        """Per-SLA-class p99 latency deltas vs a baseline run (seconds;
        negative = this run is faster).  The headline view of what
        preemption/migration bought each tier on the same arrival stream."""
        mine = self.per("tier")
        theirs = baseline.per("tier")
        return {tier: mine[tier].p99_latency_s - theirs[tier].p99_latency_s
                for tier in sorted(set(mine) & set(theirs))}

    def as_dict(self) -> dict:
        """Machine-readable summary (the BENCH_traffic.json row format).

        The ``preemptions``/``migrations`` counters — and the ``obs``
        digest — appear only when the corresponding feature was enabled,
        so records from runs predating the features regenerate
        byte-identically.
        """
        out = {
            "policy": self.policy,
            "backend": self.backend,
            "arrivals": self.arrivals,
            "dispatch": self.dispatch,
            "n_arrays": self.n_arrays,
            **self.metrics.as_dict(),
        }
        if self.preemption is not None:
            out["preemption"] = self.preemption
            out["preemptions"] = self.metrics.preemptions
        if self.rebalance is not None:
            out["rebalance"] = self.rebalance
            out["migrations"] = self.metrics.migrations
        if self.faults is not None:
            out["faults"] = self.faults
            out["recovery"] = self.recovery
        if self.memory is not None:
            out["memory"] = self.memory
        if self.overload is not None:
            out["overload"] = self.overload
        if self.timeline is not None:
            out["obs"] = self.timeline.summary()
        return out


@dataclasses.dataclass(frozen=True)
class MemoryStats:
    """Fleet contention accounting of one armed run — the duck-typed
    ``memory=`` payload :func:`repro.traffic.metrics.summarize` folds
    into the gated ``memory_*`` metrics fields."""

    stall_s: float                 # total extra bus-busy seconds
    stall_by_node: dict            # node index -> stall seconds
    peak_pressure: float           # max per-window demand / capacity


@dataclasses.dataclass(frozen=True)
class OverloadStats:
    """Overload-control accounting of one armed run — the duck-typed
    ``overload=`` payload :func:`repro.traffic.metrics.summarize` folds
    into the gated overload metrics fields."""

    rejections_by_cause: dict      # cause name -> count
    shed_by_tier: dict             # tier -> non-admitted count
    brownout_transitions: int
    brownout_energy_j: float


class _RecordBuilder:
    # dispatch_node/status0 are assigned only when tracing is armed
    # (two attribute stores per arrival — the tracer's entire hot-path
    # cost); `_derive_job_instants` reads them back lazily
    __slots__ = ("job", "array", "submitted", "completed",
                 "dispatch_node", "status0")

    def __init__(self, job: Job):
        self.job = job
        self.array: Optional[int] = None
        self.submitted: Optional[float] = None
        self.completed: Optional[float] = None

    def build(self) -> JobRecord:
        return JobRecord(job_id=self.job.job_id, model=self.job.model,
                         tier=self.job.tier, arrival=self.job.arrival,
                         deadline=self.job.deadline, array=self.array,
                         submitted=self.submitted, completed=self.completed)


def _derive_job_instants(builders: dict) -> list[tuple]:
    """Convert the run's job-record builders into raw tracer instants.

    Registered via ``Tracer.attach_source`` at end of run and evaluated
    only when the trace is read — the serving loop itself records
    nothing per job.  Kinds match ``repro.obs.tracer`` constants:
    ``dispatch`` at the arrival instant on the dispatcher's chosen node
    (with the offer status and tier), ``arrive`` at the (last)
    scheduler-submission instant, ``complete`` at the completion
    instant.  A migrated job carries its final submission here — the
    hop itself is a live ``migrate`` marker from the rebalancer."""
    out = []
    for name, b in builders.items():
        job = b.job
        t = job.arrival
        out.append(("dispatch", t, t, b.dispatch_node, name,
                    (("status", b.status0), ("tier", job.tier))))
        s = b.submitted
        if s is not None:
            out.append(("arrive", s, s, b.array, name, ()))
        c = b.completed
        if c is not None:
            out.append(("complete", c, c, b.array, name, ()))
    return out


def _host_oracle_calls() -> int:
    """Cumulative host-side cost-oracle invocations (memo hits + misses).

    The memo counters are process-global (``lru_cache`` has no per-run
    view), so :meth:`TrafficSimulator.run` snapshots a delta around its
    event loop to report oracle calls per scheduler event."""
    from repro.core.dataflow import ws_cost_cache_stats
    from repro.sim.systolic import layer_cost
    ws = ws_cost_cache_stats()
    info = layer_cost.cache_info()
    return ws["hits"] + ws["misses"] + info.hits + info.misses


class TrafficSimulator:
    """Drive an arrival stream through a fleet of partitioned arrays.

    ``arrivals`` is an :class:`~repro.traffic.arrivals.ArrivalProcess`, a
    registry name (needing ``rate``/``horizon``/... forwarded by the
    caller), or any time-ordered iterable of :class:`Job`.  ``policy`` and
    ``backend`` take `repro.api` registry names or instances.

    Runtime adaptation knobs:

    * ``preemption`` — ``True`` (default :class:`~repro.core.scheduler
      .PreemptionModel`) or a model instance arms layer-granular
      preemption on every node; only policies with a ``preempt`` hook
      (``deadline_preempt``) ever act on it.
    * ``rebalance_interval`` — seconds between cross-node migration
      ticks; enables the ``rebalancer`` strategy (name or
      :class:`~repro.traffic.rebalance.Rebalancer`, default
      ``migrate_on_pressure`` under the optional ``migration`` cost
      model), which additionally runs a pressure-only pass at every
      arrival.
    * ``check_invariants`` — re-arm the per-event
      :class:`~repro.core.partition.PartitionSet` tiling check on every
      node (a debug net the serving hot path leaves off — see
      :class:`~repro.core.scheduler.DynamicScheduler`).
    * ``fairness`` — ``True`` (or a
      :class:`~repro.fairness.drf.ResourceModel`) arms per-tenant
      fairness accounting: Jain index + per-model slowdown vs isolated
      baselines and a dominant-share series sampled at every arrival;
      the numbers land in the gated
      :class:`~repro.traffic.metrics.TrafficMetrics` fields and the raw
      report on ``ServeResult.fairness``.  Off (default) keeps every
      record byte-identical to pre-fairness runs.
    * ``faults`` — a :class:`~repro.chaos.FaultPlan` (or
      :class:`~repro.chaos.FaultEvent` / sequence of events) arms seeded
      fault injection: crashes, blackouts, column-loss degradation, bus
      stalls and stragglers hit the fleet mid-run; a
      :class:`~repro.chaos.HealthMonitor` (``monitor=``) detects failures
      at dispatch boundaries and excludes non-healthy nodes from routing;
      the ``recovery`` policy (registry name or
      :class:`~repro.chaos.RecoveryPolicy`, default ``retry_restart``)
      re-dispatches lost jobs under capped exponential backoff with
      checkpoint warm restarts, and sheds low tiers below its capacity
      watermark.  Off (default) keeps every record byte-identical to
      pre-chaos runs; the report lands on ``ServeResult.chaos`` and the
      gated metrics fields.
    * ``obs`` — ``True`` (or a :class:`~repro.obs.Observability`) arms
      structured tracing + the time-series metrics registry across the
      whole run: scheduler lifecycle spans and preemption/migration
      markers in the ring-buffered tracer, per-node/per-tenant series
      (utilization, queue depth, ready-set size, bus occupancy, dominant
      share, slowdown) in the registry, surfaced as
      ``ServeResult.timeline``.  Pure observation — the disabled path
      adds no work and armed runs serialize the identical base record
      (the gated ``obs`` key appends after the stable prefix).
    * ``memory`` — ``True`` (default
      :class:`~repro.core.scheduler.ContentionModel`) or a model instance
      arms fleet-shared DRAM bandwidth contention: every node's stage
      transfers book demand against ONE per-window bandwidth pool, and
      demand beyond capacity stretches transfers superlinearly (the
      MoCA-style interference curve).  Policies with a ``bandwidth`` hook
      (``moca``) additionally set per-tenant bandwidth caps each
      assignment round.  Off (default) keeps every record byte-identical
      to pre-contention runs; armed runs append the gated ``memory_*``
      metrics keys after the chaos gates.
    * ``admission`` / ``brownout`` — closed-loop overload control
      (`repro.overload`).  ``admission`` names an
      :class:`~repro.overload.AdmissionPolicy` (``"static"``,
      ``"codel"``, ``"token_bucket"``) or passes an instance: a
      per-arrival admit/shed decision in front of the dispatcher,
      reading the fleet's best-case queue-delay estimate; no registered
      policy ever sheds tier 0.  ``brownout`` is ``True`` (default
      :class:`~repro.overload.BrownoutController`) or a controller: the
      degrade-before-drop ladder that tightens batch bandwidth caps,
      shrinks batch column floors and stretches batch deadlines before
      shedding, with each stage transition a ``brownout`` tracer
      instant priced in energy.  Off (default) keeps every record
      byte-identical to pre-overload runs; armed runs append the gated
      overload metrics keys after the memory gates.

    All knobs may instead be passed as one
    :class:`repro.api.ServeConfig` (``config=``) — the grouped-by-
    subsystem spelling; mixing ``config=`` with flat serve keywords
    raises.  Remaining keyword arguments are forwarded to the arrivals
    registry when ``arrivals`` is a name.
    """

    def __init__(self, arrivals, policy="equal", backend="sim",
                 config=None, **kwargs):
        from repro.api.backend import resolve_backend
        from repro.api.config import resolve_serve_config
        from repro.api.policy import resolve_policy
        from repro.core.scheduler import (ContentionModel, PreemptionModel,
                                          SharedBandwidth)
        from repro.traffic.rebalance import resolve_rebalancer
        # ONE canonical knob object either way: bare serve kwargs are
        # coerced into a ServeConfig here, leftovers go to the arrivals
        # registry (repro.api.config documents the split)
        cfg, arrival_kwargs = resolve_serve_config(config, kwargs)
        self.config = cfg
        n_arrays = cfg.scheduling.n_arrays
        dispatch = cfg.scheduling.dispatch
        max_concurrent = cfg.scheduling.max_concurrent
        queue_cap = cfg.scheduling.queue_cap
        seed = cfg.scheduling.seed
        keep_trace = cfg.scheduling.keep_trace
        preemption = cfg.scheduling.preemption
        check_invariants = cfg.scheduling.check_invariants
        rebalance_interval = cfg.rebalance.interval
        rebalancer = cfg.rebalance.rebalancer
        migration = cfg.rebalance.migration
        fairness = cfg.fairness
        obs = cfg.obs
        faults = cfg.chaos.faults
        recovery = cfg.chaos.recovery
        monitor = cfg.chaos.monitor
        memory = cfg.memory.contention
        admission = cfg.overload.admission
        brownout = cfg.overload.brownout
        if n_arrays < 1:
            raise ValueError(f"n_arrays must be >= 1, got {n_arrays}")
        if rebalance_interval is not None and rebalance_interval <= 0:
            raise ValueError(f"rebalance_interval must be positive, got "
                             f"{rebalance_interval}")
        if preemption is True:
            preemption = PreemptionModel()
        elif preemption is False:
            preemption = None
        self.preemption = preemption
        self.rebalance_interval = rebalance_interval
        if rebalance_interval is not None:
            # rebalancer=None is the "caller said nothing" sentinel — the
            # default strategy name is filled in only once an interval arms
            # the feature, so naming it explicitly without an interval
            # errors like any other name (the fixed sentinel wart)
            if rebalancer is None:
                rebalancer = "migrate_on_pressure"
            if migration is not None and not isinstance(rebalancer, str):
                raise ValueError(
                    "migration= only applies when the rebalancer is built "
                    "from a registry name; configure the instance's "
                    "migration model directly instead")
            self.rebalancer = resolve_rebalancer(
                rebalancer, **({"migration": migration}
                               if migration is not None else {}))
        else:
            if migration is not None or rebalancer is not None:
                raise ValueError(
                    "rebalancer=/migration= have no effect without "
                    "rebalance_interval=; set an interval to enable "
                    "cross-node migration")
            self.rebalancer = None
        # memory contention: one ContentionModel + ONE SharedBandwidth
        # ledger across the whole fleet — concurrent partitions on every
        # node draw from the same per-window bandwidth pool
        self.contention = None
        self._shared_bw = None
        if memory:
            self.contention = (memory if isinstance(memory, ContentionModel)
                               else ContentionModel())
            self._shared_bw = SharedBandwidth(self.contention)
        if isinstance(arrivals, str):
            # one seed steers the whole run: the arrival stream inherits it
            # unless the caller seeds the process explicitly
            arrival_kwargs.setdefault("seed", seed)
        if isinstance(arrivals, (str, ArrivalProcess)):
            self.arrivals = resolve_arrivals(arrivals, **arrival_kwargs)
        else:
            if arrival_kwargs:
                raise ValueError("arrival kwargs need a registry name")
            self.arrivals = arrivals  # pre-built Job iterable
        self.policy = resolve_policy(policy)
        self.backend = resolve_backend(backend)
        self.dispatcher = resolve_dispatcher(dispatch)
        self.n_arrays = n_arrays
        self.keep_trace = keep_trace
        self._rng = random.Random(seed)
        self._builders: dict[str, _RecordBuilder] = {}
        # observability: resolved before node construction so every
        # scheduler shares the one tracer/registry bundle.  All hooks are
        # None when disabled — the hot path stays guard-only.
        self._obs = None
        self._tracer = None
        self._registry = None
        self._pulse_nodes = None
        if obs:
            # local import: repro.traffic stays importable without
            # repro.obs until the feature is actually armed
            from repro.obs import resolve_obs
            self._obs = resolve_obs(obs)
            self._tracer = self._obs.tracer
            self._registry = self._obs.registry
        time_fn = self.backend.time_fn()
        stage = self.backend.stage_model()
        self.nodes = [
            ArrayNode(i, self.backend.array, time_fn, stage, self.policy,
                      max_concurrent=max_concurrent, queue_cap=queue_cap,
                      on_complete=self._on_complete,
                      on_submit=self._on_submit, keep_trace=keep_trace,
                      preemption=preemption,
                      on_load_change=self._on_load_change,
                      check_invariants=check_invariants, obs=self._obs,
                      contention=self.contention,
                      shared_bandwidth=self._shared_bw)
            for i in range(n_arrays)]
        if self.rebalancer is not None and self._obs is not None:
            self.rebalancer.obs = self._obs   # migration instant markers
        if self._registry is not None:
            # hoisted bound handles: the arrival pulse appends via
            # pre-resolved `.sample`/`.inc` methods — no name lookups,
            # no attribute chases in the loop body
            reg = self._registry
            # node.scheduler is read inside the pulse loop (not hoisted
            # here): fault injection replaces a failed node's scheduler
            self._pulse_nodes = [
                (node,
                 reg.series(f"node{i}.in_system").sample,
                 reg.series(f"node{i}.queue_depth").sample,
                 reg.series(f"node{i}.ready").sample,
                 reg.series(f"node{i}.bus_busy_s").sample,
                 reg.series(f"node{i}.utilization").sample)
                for i, node in enumerate(self.nodes)]
            self._pulse_fleet = (reg.series("fleet.queue_depth").sample,
                                 reg.series("fleet.in_system").sample)
            self._ctr_arrivals = reg.counter("serve.arrivals")
            self._ctr_dispatch = {
                s: reg.counter(f"serve.dispatch.{s}")
                for s in ("run", "queued", "rejected")}
        # delta-maintained fleet loads: dispatch reads this instead of
        # scanning every node per arrival (O(N) -> O(log N) for jsq)
        self.fleet = FleetLoads(self.nodes)
        self.chaos = None
        if faults is not None:
            # local import: repro.traffic stays importable without
            # repro.chaos until fault injection is actually armed
            from repro.chaos import (ChaosController, HealthMonitor,
                                     resolve_faults, resolve_recovery)
            self.chaos = ChaosController(
                resolve_faults(faults), self.nodes, self.fleet,
                monitor=monitor or HealthMonitor(),
                recovery=resolve_recovery(recovery),
                seed=seed, tracer=self._tracer)
        elif recovery != "retry_restart" or monitor is not None:
            raise ValueError(
                "recovery=/monitor= have no effect without faults=; pass "
                "a FaultPlan to arm fault injection")
        # overload control (repro.overload): admission policy in front of
        # the dispatcher + brownout stage ladder over the fleet.  Both
        # default off; armed runs append the gated overload metrics keys
        # after the memory gates.
        self.admission = None
        self.brownout = None
        if admission is not None or brownout:
            # local import: repro.traffic stays importable without
            # repro.overload until a knob is actually armed
            from repro.overload import BrownoutController, resolve_admission
            if admission is not None:
                self.admission = resolve_admission(admission)
            if brownout:
                self.brownout = (brownout
                                 if isinstance(brownout, BrownoutController)
                                 else BrownoutController())
        self._overload_armed = (self.admission is not None
                                or self.brownout is not None)
        self._overload_causes = None
        self._shed_by_tier = None
        if self._overload_armed:
            self._overload_causes = {"queue_full": 0, "admission_shed": 0,
                                     "recovery_shed": 0}
            self._shed_by_tier = {}
        self.accounting = None
        if fairness:
            # local import: repro.traffic stays importable without
            # repro.fairness until the feature is actually armed
            from repro.fairness.accounting import FairnessAccounting
            from repro.fairness.drf import ResourceModel
            if isinstance(fairness, ResourceModel):
                resources = fairness
            elif stage is not None:
                # the DRF bandwidth dimension reads the *actual* staging
                # model the schedulers charge (not its estimate defaults);
                # with an armed contention model the DRF window is the
                # contention window — shares and pressure then talk about
                # the same bus-time denominator.  The sim backend's stage
                # equals ResourceModel's defaults, so default-stage runs
                # serialize byte-identically.
                resources = ResourceModel(
                    bus_bytes_per_s=stage.dram_bw_bytes,
                    bytes_per_elem=stage.bytes_per_elem,
                    **({"window_s": self.contention.window_s}
                       if self.contention is not None else {}))
            else:
                resources = None
            self.accounting = FairnessAccounting(
                self.backend.array, time_fn, stage=stage,
                n_arrays=n_arrays, resources=resources,
                backend_name=getattr(self.backend, "name",
                                     type(self.backend).__name__))

    def _on_load_change(self, node: ArrayNode) -> None:
        self.fleet.update(node)

    # -- node callbacks -----------------------------------------------------
    def _on_complete(self, node: ArrayNode, tenant: str, t: float) -> None:
        b = self._builders[tenant]
        b.completed = t
        if self.chaos is not None:
            # service-ratio observation (straggler rule) + recovered marker
            self.chaos.note_completion(node, b, t)
        if self._registry is not None and self.accounting is not None:
            # slowdown-vs-isolated sample at completion instant; observe()
            # at arrival guarantees the isolated baseline exists by now
            iso = self.accounting.isolated_s(b.job.model)
            if iso:
                self._registry.series(
                    f"tenant.{b.job.model}.slowdown").sample(
                        t, (t - b.job.arrival) / iso)

    def _on_submit(self, node: ArrayNode, job: Job, t: float) -> None:
        b = self._builders[job.dnng.name]
        b.submitted = t
        b.array = node.index  # migration may have re-homed the job

    # -- execution ----------------------------------------------------------
    def _chaos_stream(self):
        """Merge the arrival stream with released retry re-dispatches, in
        non-decreasing time order; once both drain, apply any faults still
        scheduled past the last arrival (they may release new retries)."""
        chaos = self.chaos
        cursor = 0.0
        arrivals = iter(self.arrivals)
        job = next(arrivals, None)
        while True:
            rt = chaos.next_retry_time()
            if job is not None:
                if rt is not None and rt <= job.arrival:
                    r = chaos.pop_retry(cursor)
                    cursor = r.arrival
                    yield r
                else:
                    cursor = max(cursor, job.arrival)
                    yield job
                    job = next(arrivals, None)
            elif rt is not None:
                r = chaos.pop_retry(cursor)
                cursor = r.arrival
                yield r
            else:
                ft = chaos.next_fault_time()
                if ft is None:
                    return
                chaos.advance_to(ft, self._advance)

    def _apply_brownout_stage(self) -> None:
        """Push the active brownout stage onto the fleet.

        Batch demand scale lands on every scheduler (the setter is a
        no-op at an unchanged factor); bandwidth caps land only on
        schedulers whose policy has no ``bandwidth`` hook of its own —
        a policy with one (``moca``) keeps authority over its caps (see
        :meth:`repro.api.policy.PartitionPolicy.bandwidth`).  Called on
        every stage transition, and re-called per admitted arrival while
        a capping stage is active because the tenant set the caps are
        keyed on churns with every submit/complete."""
        s = self.brownout.stage
        cap = s.batch_bw_cap if s is not None else None
        scale = s.batch_demand_scale if s is not None else 1.0
        for node in self.nodes:
            node.set_batch_demand_scale(scale)
            sched = node.scheduler
            if sched._has_bandwidth_hook:
                continue
            if cap is None:
                if sched.bus.caps:
                    sched.bus.set_caps(None)
            else:
                sched.bus.set_caps(
                    {name: cap for name, tier in sched.tiers.items()
                     if tier > 0})

    def _advance(self, t: float) -> None:
        for node in self.nodes:
            sched = node.scheduler
            events = sched._events
            if events and events[0][0] <= t:
                sched.run_until(t)
            # idle nodes are skipped outright — their clock stays at the
            # last event, which only ever under-states `now` (submissions
            # carry absolute arrival instants, so nothing depends on an
            # idle node's clock having been ticked forward)

    def run(self) -> ServeResult:
        depth_samples: list[int] = []
        last_arrival = 0.0
        interval = self.rebalance_interval
        next_tick = interval if interval is not None else None
        registry = self._registry
        tracer = self._tracer
        chaos = self.chaos
        admission = self.admission
        brown = self.brownout
        causes = self._overload_causes       # None = overload disarmed
        shed_tiers = self._shed_by_tier
        node_pes = self.backend.array.rows * self.backend.array.cols
        oracle0 = _host_oracle_calls() if registry is not None else 0
        if registry is not None:
            pulse_nodes = self._pulse_nodes
            fleet_q, fleet_in = self._pulse_fleet
            sample_every = self._obs.sample_every
            i_arr = 0
            # dispatch-status tallies kept in locals and folded into the
            # counters after the loop — two Counter.inc() calls per
            # arrival are measurable against the overhead gate
            n_run = n_queued = n_rejected = 0
        stream = self.arrivals if chaos is None else self._chaos_stream()
        for job in stream:
            last_arrival = job.arrival
            # periodic rebalance ticks up to the arrival instant
            while next_tick is not None and next_tick <= job.arrival:
                self._advance(next_tick)
                self.rebalancer.rebalance(self.nodes, next_tick,
                                          periodic=True)
                next_tick += interval
            # apply faults scheduled before the arrival, then advance every
            # array to the arrival instant, so slots freed by completions
            # before t are visible to the dispatcher
            if chaos is not None:
                chaos.advance_to(job.arrival, self._advance)
            self._advance(job.arrival)
            name = job.dnng.name
            if causes is not None:
                # overload control: one fleet queue-delay sample per
                # arrival (best case — the least-loaded node's estimate)
                # feeds both the brownout feedback loop and the
                # admission policy
                delay = min(n.wait_estimate() for n in self.nodes)
                if brown is not None:
                    hf = (chaos.healthy_capacity_frac()
                          if chaos is not None else 1.0)
                    if brown.observe(job.arrival, delay, hf):
                        self._apply_brownout_stage()
                        if tracer is not None:
                            t0, frm, to = brown.log[-1]
                            tracer.instant("brownout", t0, -1, None,
                                           (("from", frm), ("to", to)))
                    if name not in self._builders:
                        # stretch only fresh batch arrivals — a chaos
                        # retry keeps the deadline its first admission
                        # stamped (no compounding)
                        nd = brown.stretch_deadline(job.tier, job.arrival,
                                                    job.deadline)
                        if nd != job.deadline:
                            job = dataclasses.replace(job, deadline=nd)
            b = self._builders.get(name)
            if b is None:
                b = _RecordBuilder(job)
                self._builders[name] = b
            elif chaos is None or not chaos.is_retry(name):
                raise ValueError(f"duplicate job name {name!r} in "
                                 "arrival stream")
            admitted = True
            if brown is not None and brown.shed(job.tier):
                admitted = False
            if admitted and admission is not None and not admission.admit(
                    job.tier, job.arrival, delay):
                admitted = False
            if not admitted:
                target, status = None, "shed"
            elif chaos is None:
                target = self.nodes[
                    self.dispatcher.choose_tracked(self.fleet, self._rng)]
                status = target.offer(job)
                if status != "rejected":
                    b.array = target.index
            else:
                target, status = chaos.dispatch(
                    job, self.nodes, self.dispatcher, self.fleet, self._rng)
                if target is not None and status in ("run", "queued"):
                    b.array = target.index
            if causes is not None:
                if status in ("rejected", "shed"):
                    # "lost" stays out: the job was admitted and routed —
                    # losing it mid-run is chaos accounting, not a
                    # rejection cause
                    if not admitted:
                        causes["admission_shed"] += 1
                    elif status == "rejected":
                        causes["queue_full"] += 1
                    else:
                        causes["recovery_shed"] += 1
                    if status == "shed":
                        # per-tier split counts deliberate sheds only —
                        # queue_full is the tier-blind structural path,
                        # already visible in rejections_by_cause
                        shed_tiers[job.tier] = \
                            shed_tiers.get(job.tier, 0) + 1
                elif (brown is not None and brown.stage is not None
                        and brown.stage.batch_bw_cap is not None):
                    # a new tenant just entered under an active capping
                    # stage: refresh the name-keyed caps
                    self._apply_brownout_stage()
            if tracer is not None:
                # the tracer's entire per-arrival cost: the dispatch
                # choice is parked on the builder and derived into
                # dispatch/arrive/complete instants only when the trace
                # is read (`_derive_job_instants`)
                b.dispatch_node = target.index if target is not None else -1
                b.status0 = status
            if registry is not None:
                if status == "run":
                    n_run += 1
                elif status == "queued":
                    n_queued += 1
                else:
                    n_rejected += 1
            if self.rebalancer is not None:
                # deadline-pressure check at every arrival (pressure moves
                # only — full balancing happens on the periodic ticks)
                self.rebalancer.rebalance(self.nodes, job.arrival,
                                          periodic=False)
            depth_samples.append(self.fleet.queued_total)
            if registry is not None:
                # time-series pulse: fleet + every node (post-dispatch
                # occupancy) at every `sample_every`-th arrival instant —
                # source-strided so the armed pulse stays inside the
                # traffic-bench overhead gate (Observability docstring)
                if i_arr % sample_every == 0:
                    t = job.arrival
                    fleet_q(t, self.fleet.queued_total)
                    fleet_in(t, sum(self.fleet.loads))
                    for node, s_in, s_q, s_ready, s_bus, s_util \
                            in pulse_nodes:
                        sched = node.scheduler
                        q = len(node.queue)
                        s_in(t, len(sched.tenants) + q)
                        s_q(t, q)
                        s_ready(t, len(sched._ready))
                        s_bus(t, sched.bus.busy_s)
                        if t > 0.0:
                            s_util(t, node.pe_seconds_busy
                                   / (t * node_pes))
                i_arr += 1
            if self.accounting is not None:
                # fold this arrival into the fairness books: template for
                # the isolated baseline + a dominant-share sample of the
                # post-dispatch fleet occupancy (the paper's A_t instants)
                self.accounting.observe(job)
                shares = self.accounting.sample(job.arrival, self.nodes)
                if registry is not None:
                    for model, share in shares.items():
                        registry.series(
                            f"tenant.{model}.dominant_share").sample(
                                job.arrival, share)
        # arrivals exhausted: keep ticking while queues drain, then flush
        if next_tick is not None:
            while any(n.queue for n in self.nodes):
                self._advance(next_tick)
                self.rebalancer.rebalance(self.nodes, next_tick,
                                          periodic=True)
                next_tick += interval
        for node in self.nodes:
            node.scheduler.run()
        ends = ([n.scheduler.now for n in self.nodes]
                + [last_arrival, getattr(self.arrivals, "horizon", 0.0)])
        if chaos is not None:
            ends.append(chaos.last_event_t)
        end = max(ends)
        records = tuple(b.build() for b in self._builders.values())
        pes = self.backend.array.rows * self.backend.array.cols
        fairness = (self.accounting.report(records)
                    if self.accounting is not None else None)
        memory_stats = None
        if self.contention is not None:
            memory_stats = MemoryStats(
                stall_s=sum(n.bus_stall_s for n in self.nodes),
                stall_by_node={n.index: n.bus_stall_s for n in self.nodes},
                peak_pressure=self._shared_bw.peak_pressure)
        overload_stats = None
        overload_descr = None
        if self._overload_armed:
            overload_stats = OverloadStats(
                rejections_by_cause=dict(self._overload_causes),
                shed_by_tier=dict(self._shed_by_tier),
                brownout_transitions=(brown.transitions
                                      if brown is not None else 0),
                brownout_energy_j=(brown.energy_overhead_j
                                   if brown is not None else 0.0))
            parts = []
            if admission is not None:
                parts.append("admission=" + (
                    getattr(admission, "name", "")
                    or type(admission).__name__))
            if brown is not None:
                parts.append("brownout")
            overload_descr = "+".join(parts)
        metrics = summarize(
            records, duration_s=end,
            pe_seconds_busy=sum(n.pe_seconds_busy for n in self.nodes),
            total_pes=pes * self.n_arrays,
            queue_depth_samples=depth_samples,
            preemptions=sum(n.scheduler.n_preemptions for n in self.nodes),
            migrations=(self.rebalancer.n_migrations
                        if self.rebalancer is not None else 0),
            fairness=fairness, chaos=chaos, memory=memory_stats,
            overload=overload_stats)
        timeline = None
        if self._obs is not None:
            if tracer is not None:
                # lazy sources: per-job instants from the run's record
                # builders, per-layer spans from the schedulers'
                # keep_trace records — both converted at read/export
                # time (never on the benched serving path)
                builders = self._builders
                tracer.attach_source(
                    lambda: _derive_job_instants(builders))
                if self.keep_trace:
                    for node in self.nodes:
                        tracer.attach(node.index, node.scheduler.trace)
            if registry is not None:
                self._ctr_arrivals.inc(n_run + n_queued + n_rejected)
                self._ctr_dispatch["run"].inc(n_run)
                self._ctr_dispatch["queued"].inc(n_queued)
                self._ctr_dispatch["rejected"].inc(n_rejected)
                events = sum(n.scheduler.n_events for n in self.nodes)
                registry.counter("sched.events").inc(events)
                registry.counter("sched.preemptions").inc(
                    metrics.preemptions)
                registry.counter("sched.completions").inc(
                    sum(1 for r in records if r.completed is not None))
                if self.rebalancer is not None:
                    registry.counter("sched.migrations").inc(
                        self.rebalancer.n_migrations)
                if events:
                    registry.gauge("oracle.calls_per_event").set(
                        (_host_oracle_calls() - oracle0) / events)
            from repro.obs import Timeline
            timeline = Timeline(self._obs)
        return ServeResult(
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            backend=getattr(self.backend, "name",
                            type(self.backend).__name__),
            arrivals=getattr(self.arrivals, "name",
                             type(self.arrivals).__name__),
            dispatch=self.dispatcher.name or type(self.dispatcher).__name__,
            n_arrays=self.n_arrays,
            records=records, metrics=metrics,
            schedules=(tuple(n.scheduler.result() for n in self.nodes)
                       if self.keep_trace else None),
            preemption=(type(self.preemption).__name__
                        if self.preemption is not None else None),
            rebalance=(getattr(self.rebalancer, "name", None)
                       or type(self.rebalancer).__name__
                       if self.rebalancer is not None else None),
            fairness=fairness, timeline=timeline,
            faults=chaos.plan.name if chaos is not None else None,
            recovery=chaos.recovery.name if chaos is not None else None,
            chaos=chaos.report() if chaos is not None else None,
            memory=(repr(self.contention)
                    if self.contention is not None else None),
            overload=overload_descr,
            brownout=(brown.report() if brown is not None else None))


def serve(arrivals, policy="equal", backend="sim", config=None,
          **kwargs) -> ServeResult:
    """Functional one-shot: ``serve(PoissonArrivals(...), policy="equal")``.

    Knobs go in a :class:`repro.api.ServeConfig` (``config=``) or as the
    historical flat keywords — never both; leftover keywords are arrival
    constructor kwargs when ``arrivals`` is a registry name."""
    return TrafficSimulator(arrivals, policy=policy, backend=backend,
                            config=config, **kwargs).run()
