"""Min-cost-flow assignment: Firmament-style global matching.

The stock policies bind ready layers to free slices greedily (heaviest →
largest).  Firmament (Gog et al., OSDI'16 — the flow-graph scheduler the
SNIPPETS exemplar benchmarks against) shows the same decision posed as a
**min-cost max-flow** over a task→resource graph finds globally better
placements at negligible cost when the graph is small — and here it is
tiny: ready layers × free slices, both bounded by co-residency.

:func:`min_cost_assignment` is the classic successive-shortest-path
algorithm (Bellman-Ford on the residual graph; no potentials needed at
this size), deterministic under cost ties.  :class:`MinCostFlowPolicy`
(registered ``"min_cost_flow"``) prices every (layer, slice) edge with one
vectorized pass of the PR-5 batch cost oracle
(:meth:`~repro.api.policy.AssignContext.time_batch`) and returns the
matching that minimizes total predicted runtime — maximum cardinality
first, cost among max-cardinality matchings second (source→layer edges
carry a large negative credit, so leaving a layer unmatched is never
cheaper than any real edge).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

from repro.api.policy import (
    AssignContext,
    EqualPolicy,
    ReadyLayer,
    register_policy,
)
from repro.core.partition import Assignment, Partition


def min_cost_assignment(
        costs: Sequence[Sequence[float]]) -> list[tuple[int, int]]:
    """Max-cardinality, min-cost bipartite matching.

    ``costs[i][j]`` is the (finite, non-negative) cost of matching left
    node ``i`` to right node ``j``; an infinite entry forbids the edge.
    Returns matched ``(i, j)`` pairs sorted by ``i``.  Successive shortest
    paths on the flow network source→left→right→sink (unit capacities);
    Bellman-Ford tolerates the negative reduced costs of residual edges,
    and its fixed relaxation order makes tie-breaks deterministic.
    """
    n = len(costs)
    m = len(costs[0]) if n else 0
    if n == 0 or m == 0:
        return []
    src, snk = n + m, n + m + 1
    size = n + m + 2
    # edge: [to, residual capacity, cost, index of reverse edge]
    graph: list[list[list]] = [[] for _ in range(size)]

    def add(u: int, v: int, cap: int, cost: float) -> list:
        fwd = [v, cap, cost, len(graph[v])]
        graph[u].append(fwd)
        graph[v].append([u, 0, -cost, len(graph[u]) - 1])
        return fwd

    # a large negative credit per matched left node dominates any real edge
    # cost, making every augmentation that increases cardinality profitable:
    # max-cardinality first, min cost among max-cardinality matchings second
    finite = [c for row in costs for c in row if math.isfinite(c)]
    credit = sum(finite) + len(finite) + 1.0
    for i in range(n):
        add(src, i, 1, -credit)
    for j in range(m):
        add(n + j, snk, 1, 0.0)
    match_edges = []
    for i in range(n):
        for j in range(m):
            c = costs[i][j]
            if math.isfinite(c):
                match_edges.append((i, j, add(i, n + j, 1, float(c))))
    inf = math.inf
    while True:
        # Bellman-Ford shortest path src→snk over residual edges
        dist = [inf] * size
        dist[src] = 0.0
        prev: list = [None] * size
        for _ in range(size):
            improved = False
            for u in range(size):
                du = dist[u]
                if du == inf:
                    continue
                for e in graph[u]:
                    if e[1] > 0 and du + e[2] < dist[e[0]]:
                        dist[e[0]] = du + e[2]
                        prev[e[0]] = (u, e)
                        improved = True
            if not improved:
                break
        if prev[snk] is None or dist[snk] >= 0.0:
            break  # no augmenting path still profitable
        v = snk
        while v != src:
            u, e = prev[v]
            e[1] -= 1
            graph[e[0]][e[3]][1] += 1
            v = u
    return sorted((i, j) for i, j, e in match_edges if e[1] == 0)


@register_policy("min_cost_flow")
class MinCostFlowPolicy(EqualPolicy):
    """Equal splits + globally min-cost layer→slice assignment.

    ``split``/``widths`` stay Algorithm 1's equal cuts (inherited), so the
    policy is directly comparable to ``equal``: only the *binding* step
    changes.  ``assign`` prices every ready-layer × free-slice pair in one
    batch oracle pass and solves the min-cost matching — grants are whole
    slices (no trimming), so the scheduler's steady-state re-offer loop
    composes exactly as with the greedy policies.

    ``max_width_factor`` (optional) forbids edges that would strand a
    layer on a slice wider than ``max_width_factor ×`` its usable width —
    with the default ``None`` every edge is allowed and cardinality is
    limited only by counts.

    Without an oracle in the context (``ctx.time_fn is None``), costs fall
    back to the ideal-throughput proxy ``opr / n_pes``.
    """

    def __init__(self, max_width_factor: Optional[float] = None):
        if max_width_factor is not None and max_width_factor < 1.0:
            raise ValueError(f"max_width_factor must be >= 1, got "
                             f"{max_width_factor}")
        self.max_width_factor = max_width_factor

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        ready = list(ready)
        parts = list(partitions)
        if not ready or not parts:
            return []
        if ctx is not None and ctx.time_fn is not None:
            pairs = [(layer, p) for _, _, layer in ready for p in parts]
            flat = ctx.time_batch(pairs)
            costs = [flat[i * len(parts):(i + 1) * len(parts)]
                     for i in range(len(ready))]
        else:
            costs = [[layer.opr / p.n_pes for p in parts]
                     for _, _, layer in ready]
        if self.max_width_factor is not None:
            for row, (_, _, layer) in zip(costs, ready):
                limit = self.max_width_factor * self._demand_cols(layer, ctx)
                for j, p in enumerate(parts):
                    if p.cols > limit:
                        row[j] = math.inf
        out = []
        for i, j in min_cost_assignment(costs):
            tenant, idx, layer = ready[i]
            out.append(Assignment(tenant=tenant, layer_index=idx,
                                  layer=layer, partition=parts[j]))
        return out
