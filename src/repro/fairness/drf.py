"""Dominant-resource fairness over systolic-array resource vectors.

The paper's policies divide one resource — array columns — and call the
split fair when the widths match demand.  But a tenant's real footprint on
the accelerator is a *vector*: the columns it occupies, the share of the
stage-in DRAM bus its weight/IFMap transfers consume, and the SRAM the
stationary weights pin while it runs.  A column-fair split can be wildly
bus-unfair (a reduction-heavy layer moves far more bytes per column), which
is exactly the regime DRF (Ghodsi et al., NSDI'11 — the Mesos allocator the
SNIPPETS exemplar benchmarks against) was designed for: allocate by
**progressive filling** so every tenant's *dominant* share — the max of its
per-resource shares — stays as equal as floors and demands allow.

:class:`ResourceModel` maps one layer to its per-column demand vector;
:class:`DRFPolicy` (registered ``"drf"``) runs progressive filling over
those vectors inside :meth:`~repro.api.policy.PartitionPolicy.widths`, so
every consumer of the policy protocol — the dynamic scheduler, the traffic
simulator, the mesh tenancy manager — gets DRF splits unchanged.

DRF properties this implementation keeps (and tests assert):

* sharing-incentive / envy-freeness at column granularity: allocation is
  one column at a time to the tenant with the smallest dominant share
  (ties → placement order), so no tenant can end two grants ahead of
  another that wanted columns;
* strategy-proofness against demand inflation: a tenant's dominant share
  is *charged* per granted column, so overstating ``demand`` (Opr) does
  not change its fill rate;
* floors: ``min_cols`` reservations are granted first (admission by
  :func:`repro.api.policy._admit_by_floor`, same as ``proportional``);
* saturation: a tenant stops filling at its ``width_demand`` — leftover
  columns keep filling the others.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.api.policy import (
    PartitionPolicy,
    TenantDemand,
    _admit_by_floor,
    _floor_cols,
    register_policy,
)
from repro.core.dnng import LayerShape


@dataclasses.dataclass(frozen=True)
class ResourceModel:
    """Per-column resource demand vector of one layer.

    The three tracked resources, each normalized by a *capacity* so shares
    are comparable across resources (DRF only ever compares ratios, so the
    capacities are normalizers, not hard limits):

    * **columns** — 1/``total_cols`` per granted column;
    * **stage-in bus** — the layer's stage-in transfer time (weights K×M
      plus IFMap T×K over the shared DRAM bus, the
      :class:`~repro.core.scheduler.StageModel` cost), attributed evenly
      across the columns the layer can use and normalized by ``window_s``
      of bus time — the fraction of a scheduling window the tenant's
      per-column traffic keeps the bus busy;
    * **SRAM** — the stationary weights a granted column pins
      (``weight_bytes / usable width``) over ``sram_bytes``.

    Defaults follow the sim backend's constants (64 GB/s bus,
    :class:`~repro.configs.systolic.SystolicConfig` 2-byte elements) with a
    100 µs window ≈ one heavy-pool layer service and a 4 MiB per-array
    weight SRAM.
    """

    bus_bytes_per_s: float = 64e9
    window_s: float = 100e-6
    sram_bytes: float = 4 * 2**20
    bytes_per_elem: int = 2

    def usable_width(self, layer: LayerShape, total_cols: int) -> int:
        return max(1, min(layer.gemm_n, total_cols))

    def per_col_vector(self, layer: LayerShape,
                       total_cols: int) -> tuple[float, float, float]:
        """(columns, bus, sram) consumed per granted column, normalized."""
        width = self.usable_width(layer, total_cols)
        stage_elems = layer.gemm_k * (layer.gemm_n + layer.gemm_m)
        bus_s = stage_elems * self.bytes_per_elem / self.bus_bytes_per_s
        return (1.0 / total_cols,
                (bus_s / width) / self.window_s,
                (layer.weight_bytes / width) / self.sram_bytes)

    def dominant_per_col(self, layer: LayerShape, total_cols: int) -> float:
        """Dominant-share increment of one granted column: all three
        resources scale linearly with columns, so the dominant resource is
        fixed per layer and the share after ``w`` columns is ``w`` times
        this."""
        return max(self.per_col_vector(layer, total_cols))


@register_policy("drf")
class DRFPolicy(PartitionPolicy):
    """Dominant-resource-fair widths via progressive filling.

    ``widths`` grants every admitted tenant its ``min_cols`` floor, then
    hands out the remaining columns one at a time to the tenant with the
    smallest dominant share (ties → placement order), each grant charging
    the tenant its per-column dominant increment.  Tenants saturate at
    ``width_demand``.  ``assign`` stays the paper's Task_Assignment
    (heaviest → largest, whole grants): DRF is a *widths* policy, so the
    scheduler's split step is where it acts.

    Demands without a concrete ``layer`` (e.g. the mesh tenancy manager's
    serving tenants) fall back to a columns-only vector — progressive
    filling then degenerates to max-min fairness over columns, still a
    valid DRF instance with one resource.
    """

    def __init__(self, resources: ResourceModel | None = None):
        self.resources = resources or ResourceModel()

    def _dominant_per_col(self, t: TenantDemand, total_cols: int) -> float:
        if t.layer is None:
            return 1.0 / max(1, total_cols)
        return self.resources.dominant_per_col(t.layer, total_cols)

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        placed = _admit_by_floor(self.order(tenants), total_cols, _floor_cols)
        if not placed:
            return {}
        ws = {t.name: _floor_cols(t) for t in placed}
        cols_left = total_cols - sum(ws.values())
        per_col = {t.name: self._dominant_per_col(t, total_cols)
                   for t in placed}
        caps = {}
        for t in placed:
            cap = t.width_demand if t.width_demand else total_cols
            caps[t.name] = max(_floor_cols(t), min(cap, total_cols))
        rank = {t.name: i for i, t in enumerate(placed)}
        active = [t.name for t in placed if ws[t.name] < caps[t.name]]
        # progressive filling, one column per step — O(cols × tenants),
        # both small (≤1024 cols, co-residency-bounded tenant counts)
        while cols_left > 0 and active:
            name = min(active,
                       key=lambda n: (ws[n] * per_col[n], rank[n]))
            ws[name] += 1
            cols_left -= 1
            if ws[name] >= caps[name]:
                active.remove(name)
        return ws

    def dominant_share(self, layer: Optional[LayerShape], cols: int,
                       total_cols: int) -> float:
        """Dominant share of a tenant holding ``cols`` columns for
        ``layer`` — the accounting-side view (`repro.fairness.accounting`
        samples it over the in-flight set), guaranteed consistent with the
        shares :meth:`widths` equalizes."""
        if layer is None:
            return cols / max(1, total_cols)
        return cols * self.resources.dominant_per_col(layer, total_cols)
