"""Per-tenant fairness accounting: Jain index, slowdown, dominant shares.

A partition policy can look great on aggregate SLA numbers while quietly
starving one tenant — the aggregate metrics in `repro.traffic.metrics`
cannot see that.  This module adds the per-tenant view the multi-tenant
scheduling literature reports:

* **per-tenant slowdown** — mean completion latency of a tenant's jobs
  over the tenant's *isolated* service time: what one job takes alone on
  a whole array, sequential single-tenancy — literally a per-model
  :class:`~repro.api.session.BaselineRun`
  (:func:`~repro.core.scheduler.schedule_sequential`), memoized per model;
* **Jain fairness index** — ``J = (Σx)² / (n·Σx²)`` over the per-tenant
  slowdowns: 1.0 = perfectly even suffering, 1/n = one tenant absorbs it
  all;
* **dominant-share time series** — at every arrival instant the live
  column occupancy of each node
  (:meth:`~repro.core.scheduler.DynamicScheduler.inflight_allocations`)
  is folded into per-model dominant resource shares under the same
  :class:`~repro.fairness.drf.ResourceModel` the ``drf`` policy
  allocates by, so policy and meter agree on what "share" means.

The `repro.traffic.simulator.TrafficSimulator` drives this behind its
``fairness=`` flag and folds the report into the gated
:class:`~repro.traffic.metrics.TrafficMetrics` fields.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.api.session import BaselineRun
from repro.core.partition import ArrayShape
from repro.core.scheduler import StageModel, TimeFn, schedule_sequential
from repro.fairness.drf import ResourceModel


def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index ``(Σx)²/(n·Σx²)`` — 1.0 when all equal,
    1/n when one value dominates; NaN for an empty set (mirrors the
    latency percentiles' no-data convention)."""
    xs = list(values)
    if not xs:
        return float("nan")
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0  # all-zero allocations are (vacuously) even
    s = sum(xs)
    return (s * s) / (len(xs) * sq)


@dataclasses.dataclass(frozen=True)
class FairnessReport:
    """Folded fairness accounting of one serve run.

    ``per_tenant_slowdown`` maps model name → mean(latency / isolated
    service time) over the model's completed jobs (sorted keys);
    ``jain_fairness`` is the Jain index over those slowdowns.
    ``dominant_share_mean`` / ``jain_dominant_share`` summarize the
    sampled dominant-share series (None when sampling was off — e.g. the
    sharded simulator, which merges records across pods but cannot sample
    a global in-flight set); ``dominant_share_series`` keeps the raw
    ``(t, ((model, share), ...))`` samples for plotting.
    """

    jain_fairness: float
    per_tenant_slowdown: dict[str, float]
    jain_dominant_share: Optional[float] = None
    dominant_share_mean: Optional[dict[str, float]] = None
    dominant_share_series: tuple = ()


class FairnessAccounting:
    """Accumulate fairness state over one serve run.

    ``observe(job)`` (every arrival) memoizes one DNNG template per model —
    the isolated baseline is computed lazily from the template on first
    need, so the expensive sequential schedule runs once per *model*, not
    per job.  ``sample(now, nodes)`` (every arrival, optional) folds the
    fleet's in-flight allocations into per-model dominant shares,
    normalized by the fleet column count (``n_arrays ×`` per-array
    capacity); the retained series is bounded at ``max_samples`` points
    by deterministic stride-doubling decimation, so open-ended serving
    runs hold constant memory.  ``report(records)`` folds everything into
    a :class:`FairnessReport`.
    """

    def __init__(self, array: ArrayShape, time_fn: TimeFn,
                 stage: StageModel | None = None, n_arrays: int = 1,
                 resources: ResourceModel | None = None,
                 backend_name: str = "", max_samples: int = 8192):
        if max_samples < 2:
            raise ValueError(f"max_samples must be >= 2, got {max_samples}")
        self.array = array
        self.time_fn = time_fn
        self.stage = stage
        self.n_arrays = n_arrays
        self.resources = resources or ResourceModel()
        self.backend_name = backend_name
        self._templates: dict = {}   # model -> DNNG (arrival_time 0)
        self._baselines: dict[str, BaselineRun] = {}
        # bounded dominant-share reservoir: every stride-th offered sample
        # is kept; at max_samples the odd-index points drop and the stride
        # doubles — a uniform subsample with no RNG, so an open-ended run
        # holds O(max_samples) memory yet report() statistics stay unbiased
        self._samples: list[tuple] = []
        self.max_samples = max_samples
        self._stride = 1
        self._n_offered = 0

    # -- isolated baselines --------------------------------------------------
    def observe(self, job) -> None:
        """Register one arriving :class:`~repro.traffic.arrivals.Job` so
        its model's isolated baseline can be built on demand."""
        model = job.model
        if model not in self._templates:
            self._templates[model] = job.dnng.clone(name=model,
                                                    arrival_time=0.0)

    def baseline(self, model: str) -> Optional[BaselineRun]:
        """The model's isolated run (sequential single-tenancy on a whole
        array — a :class:`BaselineRun`, shared across every policy run on
        the same backend), or None for a never-observed model."""
        base = self._baselines.get(model)
        if base is None:
            g = self._templates.get(model)
            if g is None:
                return None
            sched = schedule_sequential([g], self.array, self.time_fn,
                                        stage=self.stage)
            base = BaselineRun(workload=model, schedule=sched,
                               backend=self.backend_name)
            self._baselines[model] = base
        return base

    def isolated_s(self, model: str) -> Optional[float]:
        base = self.baseline(model)
        return base.schedule.makespan if base is not None else None

    # -- dominant-share sampling ---------------------------------------------
    def sample(self, now: float, nodes) -> dict[str, float]:
        """Record per-model dominant shares of the live fleet occupancy at
        ``now`` (the paper's A_t arrival instants); returns the shares so
        callers (the simulator's obs registry) can fold them elsewhere
        without recomputing."""
        shares: dict[str, float] = {}
        total_cols = self.array.cols
        res = self.resources
        for node in nodes:
            for tenant, (layer, part) in \
                    node.scheduler.inflight_allocations().items():
                model = tenant.split("#", 1)[0]
                share = (part.cols * res.dominant_per_col(layer, total_cols)
                         / self.n_arrays)
                shares[model] = shares.get(model, 0.0) + share
        if self._n_offered % self._stride == 0:
            self._samples.append((now, tuple(sorted(shares.items()))))
            if len(self._samples) >= self.max_samples:
                del self._samples[1::2]
                self._stride *= 2
        self._n_offered += 1
        return shares

    # -- folding -------------------------------------------------------------
    def report(self, records) -> FairnessReport:
        slow: dict[str, list] = {}
        for r in records:
            lat = r.latency
            if lat is None:
                continue
            iso = self.isolated_s(r.model)
            if iso is None or iso <= 0.0:
                continue
            slow.setdefault(r.model, []).append(lat / iso)
        per = {m: sum(v) / len(v) for m, v in sorted(slow.items())}
        j_dom = dom_mean = None
        live = [pairs for _t, pairs in self._samples if pairs]
        if live:
            j_dom = (sum(jain_index([s for _m, s in pairs])
                         for pairs in live) / len(live))
            totals: dict[str, float] = {}
            for pairs in live:
                for m, s in pairs:
                    totals[m] = totals.get(m, 0.0) + s
            # mean over ALL samples (idle instants count as zero share):
            # a time-series mean, not a mean-when-present
            dom_mean = {m: tot / len(self._samples)
                        for m, tot in sorted(totals.items())}
        return FairnessReport(
            jain_fairness=jain_index(list(per.values())),
            per_tenant_slowdown=per,
            jain_dominant_share=j_dom,
            dominant_share_mean=dom_mean,
            dominant_share_series=tuple(self._samples))
