"""`repro.fairness` — fairness policies and per-tenant accounting.

The paper splits one array among tenants but never asks whether the split
is *fair*.  This package frames multi-tenant partitioning as the cloud
scheduling problem it is ("No DNN Left Behind", arXiv 1901.06887):

* :mod:`repro.fairness.drf` — dominant-resource fairness
  (:class:`DRFPolicy`, registered ``"drf"``) over per-tenant resource
  vectors (columns × stage-in bus × SRAM, :class:`ResourceModel`);
* :mod:`repro.fairness.flow` — Firmament-style min-cost max-flow
  assignment (:class:`MinCostFlowPolicy`, registered ``"min_cost_flow"``)
  priced by the batch cost oracle;
* :mod:`repro.fairness.accounting` — Jain index, per-tenant slowdown vs
  isolated :class:`~repro.api.session.BaselineRun`\\ s, and dominant-share
  time series (:class:`FairnessAccounting`), surfaced through
  ``TrafficSimulator(fairness=True)``.

Importing the package registers both policies; `repro.api.policy` does so
lazily on an unknown-name lookup, so ``get_policy("drf")`` works without
any explicit import.
"""

from repro.fairness.accounting import (
    FairnessAccounting,
    FairnessReport,
    jain_index,
)
from repro.fairness.drf import DRFPolicy, ResourceModel
from repro.fairness.flow import MinCostFlowPolicy, min_cost_assignment

__all__ = [
    "DRFPolicy", "ResourceModel",
    "MinCostFlowPolicy", "min_cost_assignment",
    "FairnessAccounting", "FairnessReport", "jain_index",
]
