"""Recovery policies — what happens to the jobs a fault took down.

The primitives already exist elsewhere in the stack; this module only
composes them:

* **capped exponential backoff** (:class:`RetryPolicy`) with per-tier
  retry budgets and seeded jitter — the release schedule for lost jobs;
* **checkpoint-based warm restart** — a tenant's completed layers were
  staged out to DRAM, so a retry replays only the un-checkpointed tail
  (:func:`truncate_dnng`) and pays the
  :class:`~repro.traffic.rebalance.MigrationModel` transit for exactly
  that remainder (the truncated entry layer's IFMap *is* the
  checkpoint);
* **graceful degradation** — when detected-healthy fleet capacity drops
  below a tier's watermark, that tier's arrivals are shed at admission
  so tier-0 latency survives the capacity loss.

Policies are registry-named (``resolve_recovery``): ``retry_restart`` is
the full recovery path, ``none`` disables re-dispatch entirely — the
comparison cell ``BENCH_chaos.json`` gates (recovery on must strictly
beat recovery off on tier-0 miss rate under the crash plan).
"""

from __future__ import annotations

import abc
import dataclasses
import random

from repro.core.dnng import DNNG
from repro.core.registry import Registry
from repro.traffic.rebalance import MigrationModel


def truncate_dnng(dnng: DNNG, completed: int, arrival_time: float) -> DNNG:
    """The un-checkpointed remainder of ``dnng`` after ``completed`` layers.

    Keeps the job's name (the record builder keys on it); a chain simply
    drops its prefix, a DAG additionally remaps edges (edges into the
    completed prefix are satisfied by checkpointed outputs and vanish).
    """
    if completed <= 0:
        return dnng.clone(arrival_time=arrival_time)
    if completed >= len(dnng.layers):
        raise ValueError(
            f"{dnng.name!r}: cannot truncate {completed} of {len(dnng.layers)} layers"
        )
    edges = None
    if dnng.edges is not None:
        edges = tuple(
            (s - completed, d - completed) for s, d in dnng.edges if s >= completed
        )
    return DNNG(
        name=dnng.name,
        layers=dnng.layers[completed:],
        arrival_time=arrival_time,
        edges=edges,
    )


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with per-tier budgets and seeded jitter.

    ``budgets[tier]`` is how many re-dispatches a lost job of that tier
    gets (tiers beyond the tuple clamp to the last entry — lower tiers
    get fewer retries, the same way they get shed first).  ``jitter_frac``
    spreads releases ±frac around the deterministic backoff using the
    run-seeded rng the controller owns, so identical seeds yield
    identical retry schedules.
    """

    base_backoff_s: float = 1e-3
    backoff_factor: float = 2.0
    max_backoff_s: float = 50e-3
    jitter_frac: float = 0.1
    budgets: tuple[int, ...] = (3, 2, 1)

    def __post_init__(self) -> None:
        if self.base_backoff_s <= 0 or self.max_backoff_s <= 0:
            raise ValueError("backoff times must be positive")
        if self.backoff_factor < 1.0:
            raise ValueError(f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError(f"jitter_frac must be in [0, 1), got {self.jitter_frac}")
        if not self.budgets or any(b < 0 for b in self.budgets):
            raise ValueError(f"budgets must be non-negative, got {self.budgets}")

    def budget(self, tier: int) -> int:
        return self.budgets[min(tier, len(self.budgets) - 1)]

    def delay_s(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_backoff_s * self.backoff_factor**attempt, self.max_backoff_s)
        if self.jitter_frac:
            d *= 1.0 + self.jitter_frac * (2.0 * rng.random() - 1.0)
        return d


def respawn_backoffs(n: int, seed_key: str,
                     retry: RetryPolicy | None = None) -> list[float]:
    """First-attempt backoff delays for ``n`` jobs re-admitted after a pod
    respawn (`repro.traffic.sharded`).

    A dead pod's in-flight jobs re-enter through the same capped-backoff
    schedule a node crash uses — one fresh attempt each, jittered by a
    dedicated ``random.Random(seed_key)`` stream so respawn recovery is
    seed-stable and independent of every other rng in the run (the pod's
    own dispatch rng is reconstructed separately by the routing
    fast-forward).
    """
    retry = retry or RetryPolicy()
    rng = random.Random(seed_key)
    return [retry.delay_s(0, rng) for _ in range(n)]


class RecoveryPolicy(abc.ABC):
    """What to do with a lost job, and when to shed under low capacity."""

    name: str = ""

    @abc.abstractmethod
    def retry_budget(self, tier: int) -> int:
        """How many re-dispatches a lost job of ``tier`` is entitled to."""

    @abc.abstractmethod
    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Backoff before re-dispatch number ``attempt`` (0-based)."""

    def checkpoint_layers(self, completed: int) -> int:
        """Layers recoverable from checkpoints given ``completed`` done."""
        return completed

    def restore_s(self, remainder: DNNG) -> float:
        """Warm-restart transit cost for the un-checkpointed remainder."""
        return 0.0

    def should_shed(self, tier: int, healthy_frac: float) -> bool:
        """Shed a ``tier`` arrival at ``healthy_frac`` detected capacity?"""
        return False


_REGISTRY = Registry("recovery policy")


def register_recovery(name: str):
    return _REGISTRY.register(name)


def list_recoveries() -> list[str]:
    return _REGISTRY.names()


def resolve_recovery(recovery) -> RecoveryPolicy:
    return _REGISTRY.resolve(recovery, RecoveryPolicy)


@register_recovery("retry_restart")
class RetryRestart(RecoveryPolicy):
    """Backoff re-dispatch + checkpoint warm restart + watermark shedding.

    ``checkpoint_every`` sets checkpoint granularity: a job that finished
    k layers restarts from the highest multiple of ``checkpoint_every``
    at or below k (1 = every layer output is a checkpoint).
    ``shed_below`` maps *tier -> capacity watermark*: a tier-T arrival is
    shed while the detected-healthy capacity fraction is below the
    watermark of any tier <= T.  Tier 0 is never shed (keys must be
    >= 1) — that is the point of graceful degradation.
    """

    def __init__(
        self,
        retry: RetryPolicy | None = None,
        migration: MigrationModel | None = None,
        checkpoint_every: int = 1,
        shed_below: dict[int, float] | None = None,
    ):
        if checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {checkpoint_every}")
        if shed_below and min(shed_below) < 1:
            raise ValueError(
                f"shed_below tiers must be >= 1 (tier 0 is never shed), "
                f"got {sorted(shed_below)}"
            )
        self.retry = retry or RetryPolicy()
        self.migration = migration or MigrationModel()
        self.checkpoint_every = checkpoint_every
        self.shed_below = dict(shed_below or {})

    def retry_budget(self, tier: int) -> int:
        return self.retry.budget(tier)

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        return self.retry.delay_s(attempt, rng)

    def checkpoint_layers(self, completed: int) -> int:
        return (completed // self.checkpoint_every) * self.checkpoint_every

    def restore_s(self, remainder: DNNG) -> float:
        return self.migration.migrate_s(remainder)

    def should_shed(self, tier: int, healthy_frac: float) -> bool:
        for t, watermark in self.shed_below.items():
            if tier >= t and healthy_frac < watermark:
                return True
        return False


@register_recovery("none")
class NoRecovery(RecoveryPolicy):
    """Detection still runs, but lost jobs stay lost — the control arm of
    the recovered-vs-unrecovered bench comparison."""

    def retry_budget(self, tier: int) -> int:
        return 0

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        return 0.0
