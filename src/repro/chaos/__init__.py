"""`repro.chaos` — seeded fault injection, detection, and recovery.

The serving stack assumes a healthy fleet; this package breaks it on
purpose, deterministically:

* :mod:`repro.chaos.faults`   — :class:`FaultPlan` schedules (crash,
  blackout, degrade, bus_stall, straggler, pod_kill);
* :mod:`repro.chaos.monitor`  — :class:`HealthMonitor` belief tracking
  (heartbeat staleness, dispatch failures, service outliers);
* :mod:`repro.chaos.recovery` — :class:`RecoveryPolicy` registry
  (``retry_restart`` backoff + checkpoint warm restart + watermark
  shedding; ``none`` for the unrecovered control arm);
* :mod:`repro.chaos.controller` — :class:`ChaosController`, the loop the
  :class:`~repro.traffic.simulator.TrafficSimulator` drives when its
  ``faults=`` knob is armed.

With ``faults=None`` (the default) nothing here is even imported and
every serialized record stays byte-identical to pre-chaos runs — the
purity contract ``BENCH_chaos.json`` and the record-stability tests pin.
"""

from repro.chaos.controller import ChaosController, ChaosReport
from repro.chaos.faults import FAULT_KINDS, FaultEvent, FaultPlan, resolve_faults
from repro.chaos.monitor import HealthMonitor
from repro.chaos.recovery import (
    NoRecovery,
    RecoveryPolicy,
    RetryPolicy,
    RetryRestart,
    list_recoveries,
    register_recovery,
    resolve_recovery,
    respawn_backoffs,
    truncate_dnng,
)

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "resolve_faults",
    "HealthMonitor",
    "RetryPolicy",
    "RecoveryPolicy",
    "RetryRestart",
    "NoRecovery",
    "register_recovery",
    "list_recoveries",
    "resolve_recovery",
    "respawn_backoffs",
    "truncate_dnng",
    "ChaosController",
    "ChaosReport",
]
