"""Failure detection — heartbeat staleness + service-estimate outliers.

A real fleet never sees a "crash event"; it sees heartbeats stop and tail
latencies blow up.  :class:`HealthMonitor` models exactly that at the
dispatch boundary (the simulator calls :meth:`refresh` before every
routing decision):

* **heartbeat staleness** — a dead node's last heartbeat is its failure
  instant; once the gap exceeds ``suspect_after_s`` the node is
  *suspect*, past ``dead_after_s`` it is *dead*.  Detection latency is
  therefore deterministic given the arrival stream: the first refresh at
  ``t >= fail_t + dead_after_s`` flips the belief;
* **dispatch failure** — routing a job to a node that is actually down
  is a definitive signal (the RPC fails): the node is marked dead
  immediately, costing one lost job instead of a staleness wait;
* **service outliers** — a straggler (gray failure) heartbeats fine but
  completes slowly.  Completions feed a per-node EWMA of
  ``observed service / estimate``; a node whose EWMA exceeds
  ``outlier_factor ×`` the fleet median (with ``min_observations``
  samples) is suspect.  After ``probe_after_s`` it is re-probed: stats
  reset, node readmitted — if it is still slow it re-trips after
  another ``min_observations`` completions.

Beliefs (``healthy`` / ``suspect`` / ``dead``) live on
``ArrayNode.health``; truth lives on ``ArrayNode.alive``.  The monitor
only ever *reads* truth through the heartbeat model — dispatchers act on
belief via :meth:`~repro.traffic.cluster.FleetLoads.exclude` /
``readmit``, so an undetected failure still eats jobs (the realistic
window the retry path exists for).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"


@dataclasses.dataclass
class HealthMonitor:
    """Classify fleet nodes at dispatch boundaries; drive exclusion."""

    suspect_after_s: float = 2e-3  # heartbeat gap -> suspect
    dead_after_s: float = 5e-3  # heartbeat gap -> dead
    outlier_factor: float = 3.0  # EWMA vs fleet median -> suspect
    min_observations: int = 3  # completions before the ratio rule arms
    ewma_alpha: float = 0.3
    probe_after_s: float = 20e-3  # suspected-straggler re-probe interval

    def __post_init__(self) -> None:
        if not 0.0 < self.suspect_after_s <= self.dead_after_s:
            raise ValueError(
                f"need 0 < suspect_after_s <= dead_after_s, got "
                f"{self.suspect_after_s}, {self.dead_after_s}"
            )
        if self.outlier_factor <= 1.0:
            raise ValueError(f"outlier_factor must be > 1, got {self.outlier_factor}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")
        self._ratio: dict[int, float] = {}  # node -> service-ratio EWMA
        self._n_obs: dict[int, int] = {}
        self._suspected_at: dict[int, float] = {}  # straggler probation start
        # (t, node, old, new, cause) belief transitions, in detection order
        self.transitions: list[tuple[float, int, str, str, str]] = []

    # -- signal feeds -------------------------------------------------------
    def observe(self, node_index: int, ratio: float, now: float) -> None:
        """Fold one completion's ``observed/estimated`` service ratio."""
        prev = self._ratio.get(node_index)
        if prev is None:
            self._ratio[node_index] = ratio
            self._n_obs[node_index] = 1
        else:
            a = self.ewma_alpha
            self._ratio[node_index] = (1.0 - a) * prev + a * ratio
            self._n_obs[node_index] += 1

    def note_dispatch_failure(self, node, fleet, now: float) -> None:
        """A routed job hit a down node: the failed RPC is proof of death."""
        if node.health != DEAD:
            self._transition(now, node, DEAD, "dispatch_failure")
            fleet.exclude(node.index)

    # -- classification -----------------------------------------------------
    def _transition(self, now: float, node, new: str, cause: str) -> None:
        self.transitions.append((now, node.index, node.health, new, cause))
        node.health = new

    def refresh(self, now: float, nodes: Sequence, fleet) -> int:
        """Re-classify every node; sync fleet exclusion.  Returns how many
        transitions fired (the caller emits tracer markers off
        :attr:`transitions`)."""
        n0 = len(self.transitions)
        ratios = self._ratio
        n_obs = self._n_obs
        # fleet median service ratio over armed, believed-up nodes — the
        # straggler baseline (a mostly-healthy fleet pins it near 1.0)
        armed = sorted(
            ratios[n.index]
            for n in nodes
            if n.health != DEAD
            and n_obs.get(n.index, 0) >= self.min_observations
        )
        median = armed[len(armed) // 2] if armed else 0.0
        for node in nodes:
            i = node.index
            # heartbeat staleness: truth reaches belief only through this
            stale = 0.0 if node.alive else now - node.down_since
            if stale >= self.dead_after_s:
                if node.health != DEAD:
                    self._transition(now, node, DEAD, "heartbeat_lost")
                fleet.exclude(i)
                continue
            if stale >= self.suspect_after_s:
                if node.health == HEALTHY:
                    self._transition(now, node, SUSPECT, "heartbeat_stale")
                fleet.exclude(i)
                continue
            if not node.alive:
                # down, but the heartbeat gap is still below the suspect
                # threshold: undetectable by staleness.  A belief already
                # non-healthy (e.g. a definitive dispatch_failure) must
                # NOT be reset by the fresh-looking gap — keep it, and
                # keep the node excluded, until the node really returns.
                if node.health != HEALTHY:
                    fleet.exclude(i)
                continue
            # node is up: clear any stale non-healthy belief
            if node.health == DEAD:
                # blackout repair: the heartbeat is back
                self._transition(now, node, HEALTHY, "heartbeat_back")
                self._reset_stats(i)
                fleet.readmit(i)
                continue
            if node.health == SUSPECT and i in self._suspected_at:
                if now - self._suspected_at[i] >= self.probe_after_s:
                    # probation over: reset stats, readmit, re-judge fresh
                    del self._suspected_at[i]
                    self._transition(now, node, HEALTHY, "probe_ok")
                    self._reset_stats(i)
                    fleet.readmit(i)
                continue
            if node.health == SUSPECT:
                # heartbeat-suspect node came back before dead_after_s
                self._transition(now, node, HEALTHY, "heartbeat_back")
                fleet.readmit(i)
                continue
            # healthy + fresh heartbeat: service-outlier rule
            if (
                median > 0.0
                and n_obs.get(i, 0) >= self.min_observations
                and ratios[i] >= self.outlier_factor * median
            ):
                self._transition(now, node, SUSPECT, "service_outlier")
                self._suspected_at[i] = now
                fleet.exclude(i)
        return len(self.transitions) - n0

    def _reset_stats(self, i: int) -> None:
        self._ratio.pop(i, None)
        self._n_obs.pop(i, None)
        self._suspected_at.pop(i, None)
