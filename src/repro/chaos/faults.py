"""Seeded fault schedules — the injection half of `repro.chaos`.

A :class:`FaultPlan` is a time-sorted tuple of :class:`FaultEvent`, built
explicitly or sampled by :meth:`FaultPlan.seeded` from a seed.  Every
fault the serving fleet can suffer is one event kind:

* ``crash``     — permanent node loss at ``t`` (all resident jobs lost);
* ``blackout``  — transient loss: the node dies at ``t`` and comes back
  empty after ``duration_s`` of repair;
* ``degrade``   — ``dead_cols`` columns of the node's systolic array die;
  the node keeps serving on the shrunken :class:`~repro.core.partition
  .ArrayShape` and resident partitions are re-fit by the live
  :class:`~repro.api.policy.PartitionPolicy`;
* ``bus_stall`` — the node's stage-in/out bus slows by ``factor``× for
  ``duration_s`` (0 = permanently);
* ``straggler`` — the node's compute slows by ``factor``× for
  ``duration_s`` (0 = permanently) — the classic gray failure the
  :class:`~repro.chaos.monitor.HealthMonitor` must catch from service
  outliers, not heartbeats;
* ``pod_kill``  — a :class:`~repro.traffic.sharded
  .ShardedTrafficSimulator` worker process (``node`` = pod index) is
  killed at the start of epoch ``epoch``.  Only the sharded simulator
  accepts this kind; the single-process simulator rejects it.

Plans are pure data: applying them is :class:`~repro.chaos.controller
.ChaosController`'s job.  Two plans built from the same seed are equal —
the determinism contract ``BENCH_chaos.json`` pins.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Sequence

FAULT_KINDS = ("crash", "blackout", "degrade", "bus_stall", "straggler", "pod_kill")
# kinds whose effect ends after duration_s (0 = permanent)
_WINDOW_KINDS = ("bus_stall", "straggler")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: what breaks, where, when, and how badly."""

    t: float
    kind: str
    node: int = 0  # array-node index ("pod_kill": pod index)
    duration_s: float = 0.0  # blackout repair time / stall|straggle window
    factor: float = 1.0  # bus_stall / straggler slowdown multiplier
    dead_cols: int = 0  # degrade: columns lost
    epoch: int = 0  # pod_kill: epoch index the worker dies at

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if self.t < 0.0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.node < 0:
            raise ValueError(f"fault node must be >= 0, got {self.node}")
        if self.duration_s < 0.0:
            raise ValueError(f"duration_s must be >= 0, got {self.duration_s}")
        if self.kind == "blackout" and self.duration_s <= 0.0:
            raise ValueError("blackout needs a positive duration_s (repair time)")
        if self.kind in _WINDOW_KINDS and self.factor <= 1.0:
            raise ValueError(
                f"{self.kind} needs a slowdown factor > 1, got {self.factor}"
            )
        if self.kind == "degrade" and self.dead_cols < 1:
            raise ValueError(f"degrade needs dead_cols >= 1, got {self.dead_cols}")
        if self.kind == "pod_kill" and self.epoch < 0:
            raise ValueError(f"pod_kill epoch must be >= 0, got {self.epoch}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A named, time-sorted schedule of fault events."""

    events: tuple[FaultEvent, ...] = ()
    name: str = "plan"

    def __post_init__(self) -> None:
        ordered = tuple(
            sorted(self.events, key=lambda e: (e.t, e.node, e.kind, e.epoch))
        )
        object.__setattr__(self, "events", ordered)

    def __len__(self) -> int:
        return len(self.events)

    def kinds(self) -> dict[str, int]:
        """Histogram of event kinds (sorted keys) — the bench's plan digest."""
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return dict(sorted(out.items()))

    @classmethod
    def single(cls, kind: str, t: float, node: int = 0, **kw) -> "FaultPlan":
        """One-event plan — the common test/example shape."""
        return cls(events=(FaultEvent(t=t, kind=kind, node=node, **kw),), name=kind)

    @classmethod
    def seeded(
        cls,
        seed: int,
        horizon: float,
        n_nodes: int,
        *,
        crashes: int = 0,
        blackouts: int = 0,
        degrades: int = 0,
        bus_stalls: int = 0,
        stragglers: int = 0,
        dead_cols: int = 16,
        stall_factor: float = 4.0,
        straggler_factor: float = 3.0,
        repair_frac: float = 0.2,
        window: tuple[float, float] = (0.25, 0.75),
        name: str | None = None,
    ) -> "FaultPlan":
        """Sample a deterministic plan from ``seed``.

        Event times are uniform in ``window`` (fractions of ``horizon``),
        nodes uniform over the fleet; blackout repair and stall/straggle
        windows last ``repair_frac × horizon``.  The same arguments always
        yield an equal plan — seeded-regeneration identity is a pinned
        flag in ``BENCH_chaos.json``.
        """
        if horizon <= 0 or n_nodes < 1:
            raise ValueError(
                f"need horizon > 0 and n_nodes >= 1, got {horizon}, {n_nodes}"
            )
        rng = random.Random(f"faultplan:{seed}")
        lo, hi = window[0] * horizon, window[1] * horizon
        events = []
        for kind, count in (
            ("crash", crashes),
            ("blackout", blackouts),
            ("degrade", degrades),
            ("bus_stall", bus_stalls),
            ("straggler", stragglers),
        ):
            for _ in range(count):
                t = rng.uniform(lo, hi)
                node = rng.randrange(n_nodes)
                if kind == "crash":
                    events.append(FaultEvent(t=t, kind=kind, node=node))
                elif kind == "blackout":
                    events.append(
                        FaultEvent(
                            t=t, kind=kind, node=node, duration_s=repair_frac * horizon
                        )
                    )
                elif kind == "degrade":
                    events.append(
                        FaultEvent(t=t, kind=kind, node=node, dead_cols=dead_cols)
                    )
                elif kind == "bus_stall":
                    events.append(
                        FaultEvent(
                            t=t,
                            kind=kind,
                            node=node,
                            factor=stall_factor,
                            duration_s=repair_frac * horizon,
                        )
                    )
                else:
                    events.append(
                        FaultEvent(
                            t=t,
                            kind=kind,
                            node=node,
                            factor=straggler_factor,
                            duration_s=repair_frac * horizon,
                        )
                    )
        return cls(events=tuple(events), name=name or f"seeded-{seed}")


def resolve_faults(faults) -> FaultPlan:
    """Coerce a plan / event / event sequence into a :class:`FaultPlan`."""
    if isinstance(faults, FaultPlan):
        return faults
    if isinstance(faults, FaultEvent):
        return FaultPlan(events=(faults,), name=faults.kind)
    if isinstance(faults, Sequence) and not isinstance(faults, (str, bytes)):
        events = tuple(faults)
        if all(isinstance(e, FaultEvent) for e in events):
            return FaultPlan(events=events)
    raise ValueError(
        f"faults= takes a FaultPlan, a FaultEvent, or a sequence of "
        f"FaultEvent, got {type(faults).__name__}"
    )
