"""The chaos control loop: apply faults, detect, schedule recovery.

:class:`ChaosController` is the one object the traffic simulator talks
to.  It owns the expanded fault schedule (a blackout becomes a fail plus
a repair action, a stall window a set plus a reset), the retry release
heap, the run-seeded jitter rng, the :class:`~repro.chaos.monitor
.HealthMonitor`, the :class:`~repro.chaos.recovery.RecoveryPolicy`, and
the fault/retry counters that surface through the gated
:class:`~repro.traffic.metrics.TrafficMetrics` fields.

Determinism: fault application and retry releases are heap-ordered with
sequence tie-breaks; all jitter comes from one rng seeded from the run
seed — two runs with the same seed and plan produce byte-identical
records and an identical :class:`ChaosReport`.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import random

from repro.chaos.faults import FaultPlan
from repro.chaos.monitor import HealthMonitor
from repro.chaos.recovery import RecoveryPolicy, truncate_dnng


@dataclasses.dataclass(frozen=True)
class ChaosReport:
    """End-of-run fault/recovery accounting (``ServeResult.chaos``)."""

    plan: str
    recovery: str
    faults_injected: int
    jobs_lost: int
    jobs_retried: int
    jobs_recovered: int
    retries_exhausted: int
    jobs_shed: int
    detections: int
    # monitor belief transitions: (t, node, old, new, cause)
    transitions: tuple[tuple, ...] = ()
    # tier -> recovery-shed count (kept out of as_dict: the per-tier
    # split serializes through the gated overload metrics keys)
    sheds_by_tier: tuple[tuple, ...] = ()

    def as_dict(self) -> dict:
        return {
            "plan": self.plan,
            "recovery": self.recovery,
            "faults_injected": self.faults_injected,
            "jobs_lost": self.jobs_lost,
            "jobs_retried": self.jobs_retried,
            "jobs_recovered": self.jobs_recovered,
            "retries_exhausted": self.retries_exhausted,
            "jobs_shed": self.jobs_shed,
            "detections": self.detections,
        }


class ChaosController:
    """Drive one :class:`FaultPlan` through a fleet during a serve run."""

    def __init__(
        self,
        plan: FaultPlan,
        nodes,
        fleet,
        monitor: HealthMonitor,
        recovery: RecoveryPolicy,
        seed: int = 0,
        tracer=None,
    ):
        for e in plan.events:
            if e.kind == "pod_kill":
                raise ValueError(
                    "pod_kill faults target ShardedTrafficSimulator pods; "
                    "TrafficSimulator runs in one process"
                )
            if e.node >= len(nodes):
                raise ValueError(
                    f"fault targets node {e.node}, fleet has {len(nodes)}"
                )
        self.plan = plan
        self.nodes = nodes
        self.fleet = fleet
        self.monitor = monitor
        self.recovery = recovery
        self.tracer = tracer
        self._rng = random.Random(f"chaos:{seed}")
        self._seq = itertools.count()
        # (t, seq, action, payload): "fault" applies a FaultEvent; the
        # derived actions end transient effects
        self._sched: list[tuple] = []
        for e in plan.events:
            self._push(e.t, "fault", e)
        # (release_t, seq, Job, remainder DNNG template) — re-stamped with
        # the final (floor-clamped) arrival when popped
        self._retries: list[tuple] = []
        self._attempts: dict[str, int] = {}
        self._recovered: set[str] = set()
        self._nominal_cols = sum(n.array.cols for n in nodes)
        self.last_event_t = 0.0
        # counters (surfaced via TrafficMetrics gated fields)
        self.faults_injected = 0
        self.jobs_lost = 0
        self.jobs_retried = 0
        self.jobs_recovered = 0
        self.retries_exhausted = 0
        self.jobs_shed = 0
        # tier -> recovery-shed count; feeds the gated overload metrics
        # (rejection cause "recovery_shed") when admission/brownout is
        # armed alongside faults
        self.sheds_by_tier: dict[int, int] = {}
        self.detections = 0

    def _push(self, t: float, action: str, payload) -> None:
        heapq.heappush(self._sched, (t, next(self._seq), action, payload))

    # -- fault application --------------------------------------------------
    def next_fault_time(self) -> float | None:
        return self._sched[0][0] if self._sched else None

    def advance_to(self, t: float, advance_fn) -> None:
        """Apply every scheduled action due at or before ``t``, advancing
        the fleet to each action's instant first (completions before the
        fault instant must land; work after it is lost)."""
        while self._sched and self._sched[0][0] <= t:
            te, _, action, payload = heapq.heappop(self._sched)
            advance_fn(te)
            self._apply(te, action, payload)
            self.last_event_t = te

    def _mark(self, kind: str, t: float, node: int, args: tuple) -> None:
        if self.tracer is not None:
            self.tracer.instant(kind, t, node, None, args)

    def _apply(self, te: float, action: str, payload) -> None:
        if action == "fault":
            e = payload
            node = self.nodes[e.node]
            self.faults_injected += 1
            self._mark("fault", te, e.node, (("kind", e.kind),))
            if e.kind == "crash":
                self._fail_node(node, te, "crash")
            elif e.kind == "blackout":
                self._fail_node(node, te, "blackout")
                self._push(te + e.duration_s, "repair", e.node)
            elif e.kind == "degrade":
                if e.dead_cols >= node.array.cols:
                    # nothing left to serve on: a full-width loss is a crash
                    self._fail_node(node, te, "degrade")
                else:
                    for job, done in node.degrade(te, e.dead_cols):
                        self._lose(job, done, te, "degrade_overflow")
            elif e.kind == "bus_stall":
                node.set_bus_scale(e.factor)
                if e.duration_s > 0.0:
                    self._push(te + e.duration_s, "bus_ok", e.node)
            else:  # "straggler"
                node.set_compute_scale(e.factor)
                if e.duration_s > 0.0:
                    self._push(te + e.duration_s, "compute_ok", e.node)
        elif action == "repair":
            self.nodes[payload].repair(te)
            self._mark("recover", te, payload, (("cause", "repair"),))
        elif action == "bus_ok":
            self.nodes[payload].set_bus_scale(1.0)
        else:  # "compute_ok"
            self.nodes[payload].set_compute_scale(1.0)

    def _fail_node(self, node, te: float, cause: str) -> None:
        for job, done in node.fail(te):
            self._lose(job, done, te, cause)

    # -- loss + retry -------------------------------------------------------
    def _lose(self, job, completed: int, now: float, cause: str) -> None:
        """One job just vanished with ``completed`` layers checkpointed.
        Schedule its re-dispatch, or burn it if the budget is spent."""
        self.jobs_lost += 1
        name = job.dnng.name
        attempts = self._attempts.get(name, 0)
        budget = self.recovery.retry_budget(job.tier)
        if attempts >= budget:
            if budget > 0:
                self.retries_exhausted += 1
            return
        ckpt = self.recovery.checkpoint_layers(completed)
        remainder = truncate_dnng(job.dnng, ckpt, arrival_time=now)
        release = (
            now
            + self.recovery.backoff_s(attempts, self._rng)
            + self.recovery.restore_s(remainder)
        )
        self._attempts[name] = attempts + 1
        self.jobs_retried += 1
        heapq.heappush(
            self._retries, (release, next(self._seq), job, remainder)
        )

    def is_retry(self, name: str) -> bool:
        return name in self._attempts

    def next_retry_time(self) -> float | None:
        return self._retries[0][0] if self._retries else None

    def pop_retry(self, floor: float):
        """The next released retry as a re-dispatchable Job; its arrival is
        clamped to ``floor`` (the stream cursor) so the merged job stream
        stays time-ordered."""
        release, _, job, remainder = heapq.heappop(self._retries)
        t = max(release, floor)
        return dataclasses.replace(
            job, arrival=t, dnng=remainder.clone(arrival_time=t)
        )

    # -- dispatch boundary --------------------------------------------------
    def healthy_capacity_frac(self) -> float:
        """Detected-healthy column fraction of the nominal fleet — the
        graceful-degradation watermark input.  Belief-based: an undetected
        failure still counts as capacity (shedding cannot react faster
        than detection)."""
        up = sum(n.array.cols for n in self.nodes if n.health == "healthy")
        return up / self._nominal_cols

    def dispatch(self, job, nodes, dispatcher, fleet, rng):
        """The chaos-armed dispatch path: refresh beliefs, shed if the
        fleet is under water, route, and turn a dead-target route into a
        loss.  Returns ``(target_or_None, status)`` where status extends
        the offer statuses with ``"shed"`` and ``"lost"``."""
        now = job.arrival
        fired = self.monitor.refresh(now, nodes, fleet)
        if fired:
            self.detections += fired
            for t, idx, old, new, cause in self.monitor.transitions[-fired:]:
                self._mark(
                    "detect", t, idx, (("from", old), ("to", new), ("cause", cause))
                )
        if self.recovery.should_shed(job.tier, self.healthy_capacity_frac()):
            self.jobs_shed += 1
            self.sheds_by_tier[job.tier] = (
                self.sheds_by_tier.get(job.tier, 0) + 1)
            return None, "shed"
        target = nodes[dispatcher.choose_tracked(fleet, rng)]
        if target.health == "dead":
            # only the all-excluded fallback can route here (a detected-
            # dead node is excluded, and an idle dead node wins the raw
            # argmin at load 0).  A believed-suspect node beats a
            # believed-dead one — re-route on belief, never on truth.
            believed_up = [n for n in nodes if n.health != "dead"]
            if believed_up:
                target = min(believed_up, key=lambda n: (n.in_system, n.index))
        if not target.alive:
            # the routing RPC fails: definitive detection + one lost job
            self.monitor.note_dispatch_failure(target, fleet, now)
            self.detections += 1
            self._mark(
                "detect",
                now,
                target.index,
                (("from", "healthy"), ("to", "dead"), ("cause", "dispatch_failure")),
            )
            self._lose(job, 0, now, "dispatch_dead")
            return target, "lost"
        return target, target.offer(job)

    def note_completion(self, node, builder, t: float) -> None:
        """Completion feed: service-ratio observation for the straggler
        rule, plus the recovered marker for retried jobs."""
        name = builder.job.dnng.name
        if builder.submitted is not None:
            est = node.service_estimate(builder.job.dnng)
            if est > 0.0:
                self.monitor.observe(node.index, (t - builder.submitted) / est, t)
        if name in self._attempts and name not in self._recovered:
            self._recovered.add(name)
            self.jobs_recovered += 1
            if self.tracer is not None:
                self.tracer.instant(
                    "recover",
                    t,
                    node.index,
                    name,
                    (("attempts", self._attempts[name]),),
                )

    # -- results ------------------------------------------------------------
    def report(self) -> ChaosReport:
        return ChaosReport(
            plan=self.plan.name,
            recovery=self.recovery.name,
            faults_injected=self.faults_injected,
            jobs_lost=self.jobs_lost,
            jobs_retried=self.jobs_retried,
            jobs_recovered=self.jobs_recovered,
            retries_exhausted=self.retries_exhausted,
            jobs_shed=self.jobs_shed,
            detections=self.detections,
            transitions=tuple(self.monitor.transitions),
            sheds_by_tier=tuple(sorted(self.sheds_by_tier.items())),
        )
