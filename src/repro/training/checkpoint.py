"""Sharded checkpointing with atomic commit + elastic re-shard on restore.

Layout of one checkpoint::

    <dir>/step_000123/
        manifest.json        # step, leaf paths, shapes, dtypes, tree hash
        shard_h0000.npz      # this host's leaf arrays (single-host: all)
    <dir>/step_000123.tmp/   # staging dir; atomic os.replace on commit

Crash-safety: writers stage into ``.tmp`` and ``os.replace`` to the final
name only after everything (manifest last) is flushed — a reader never sees
a half-written checkpoint, and ``latest_step`` ignores ``.tmp`` leftovers.

Elastic restore: arrays are materialised host-side then ``device_put`` with
the *target* mesh's shardings — restoring onto a different mesh shape or a
different tenant slice (the paper's merge/rebalance!) is the same code path.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _tree_signature(flat: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(str(flat[k].shape).encode())
        h.update(str(flat[k].dtype).encode())
    return h.hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Write checkpoint atomically; returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    # npz cannot store ml_dtypes (bfloat16 etc.) — persist as a same-width
    # uint view and record the true dtype in the manifest.
    dtypes: dict[str, str] = {}
    storable: dict[str, np.ndarray] = {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        if v.dtype.kind not in "biufc":
            v = v.view(np.dtype(f"u{v.dtype.itemsize}"))
        storable[k] = v
    np.savez(os.path.join(tmp, "shard_h0000.npz"), **storable)
    manifest = {
        "step": step,
        "signature": _tree_signature(flat),
        "n_leaves": len(flat),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Highest committed step (ignores .tmp staging dirs), or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, name,
                                                "manifest.json")):
            steps.append(int(name[len("step_"):]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    """Load ``step`` into the structure of ``like`` (pytree of arrays/SDS).

    ``shardings`` (optional pytree of NamedSharding) re-shards every leaf
    onto the target mesh — the elastic-scaling path: the checkpoint's
    original mesh shape is irrelevant.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_h0000.npz"))

    like_flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(like):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        like_flat[key] = np.broadcast_to(np.zeros((), leaf.dtype), leaf.shape)
    sig = _tree_signature(like_flat)
    if sig != manifest["signature"]:
        raise ValueError(
            f"checkpoint structure mismatch: ckpt {manifest['signature']} "
            f"vs target {sig} (did the model config change?)")

    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree.structure(like)
    out_leaves = []
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_with_path))
    for (path, leaf), sh in zip(leaves_with_path, sh_leaves):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = data[key]
        true_dtype = manifest.get("dtypes", {}).get(key)
        if true_dtype and str(arr.dtype) != true_dtype:
            arr = arr.view(np.dtype(leaf.dtype))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        val = jnp.asarray(arr)
        if sh is not None:
            val = jax.device_put(val, sh)
        out_leaves.append(val)
    return jax.tree.unflatten(treedef, out_leaves)


def restore_latest(ckpt_dir: str, like: Any,
                   shardings: Any | None = None) -> tuple[int, Any] | None:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return step, restore(ckpt_dir, step, like, shardings)
