"""Train-step factories: GSPMD (jit) path and shard_map DP path.

Two step builders, one contract — ``step(params, opt_state, batch) ->
(params, opt_state, metrics)``:

* :func:`make_train_step` — the production path.  ``jax.jit`` with
  NamedSharding in/out specs; GSPMD inserts the collectives (this is what
  the multi-pod dry-run lowers).  Microbatch gradient accumulation happens
  *inside* the jit via ``lax.scan`` over microbatches (keeps HLO size O(1)
  in the accumulation factor).
* :func:`make_dp_train_step` — explicit data-parallel shard_map over the
  ("pod","data") axes with **gradient compression** (int8 / top-k with error
  feedback) on the cross-replica reduction, hierarchically: reduce inside a
  pod over "data", then across pods over "pod" — the two-level tree an ICI/
  DCN deployment uses.  Params are replicated in this path (pure DP); the
  GSPMD path covers FSDP+TP.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compression import (
    CompressionConfig,
    compressed_mean,
    init_error_state,
)
from repro.distributed.sharding import (
    MeshRules,
    FSDP_TP,
    batch_axes,
    batch_shardings,
    params_shardings,
)
from repro.models.model import ModelConfig, init_params, loss_fn
from repro.training.optimizer import (
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1          # grad-accumulation factor
    compression: CompressionConfig = CompressionConfig()


def _split_micro(batch: Any, n: int) -> Any:
    """(B, ...) -> (n, B/n, ...) for lax.scan accumulation."""
    def r(x):
        B = x.shape[0]
        if B % n:
            raise ValueError(f"batch {B} not divisible by microbatches {n}")
        return x.reshape((n, B // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def loss_and_grads(cfg: ModelConfig, params: Any, batch: Any,
                   microbatches: int = 1) -> tuple[jax.Array, Any]:
    """Mean loss + grads, with scan-based microbatch accumulation."""
    if microbatches == 1:
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    micro = _split_micro(batch, microbatches)

    def body(carry, mb):
        loss_acc, g_acc = carry
        lv, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, mb))(params)
        return (loss_acc + lv,
                jax.tree.map(jnp.add, g_acc, g)), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


# ---------------------------------------------------------------------------
# GSPMD path (jit + NamedSharding) — what the dry-run lowers
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    train: TrainConfig = TrainConfig(),
                    rules: MeshRules = FSDP_TP,
                    donate: bool = True) -> Callable:
    """jit'd (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        loss, grads = loss_and_grads(cfg, params, batch, train.microbatches)
        new_params, new_state = adamw_update(train.opt, params, grads,
                                             opt_state)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "step": new_state["step"]}
        return new_params, new_state, metrics

    def shardings_for(params_tree, opt_tree, batch_tree):
        p_sh = params_shardings(params_tree, mesh, rules)
        o_sh = {
            "master": params_shardings(opt_tree["master"], mesh, rules),
            "m": params_shardings(opt_tree["m"], mesh, rules),
            "v": params_shardings(opt_tree["v"], mesh, rules),
            "step": NamedSharding(mesh, P()),
        }
        b_sh = batch_shardings(batch_tree, mesh)
        m_sh = {"loss": NamedSharding(mesh, P()),
                "grad_norm": NamedSharding(mesh, P()),
                "step": NamedSharding(mesh, P())}
        return (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh)

    def jitted(params_tree, opt_tree, batch_tree):
        in_sh, out_sh = shardings_for(params_tree, opt_tree, batch_tree)
        return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1) if donate else ())

    return step, jitted


def init_sharded(cfg: ModelConfig, mesh: Mesh, seed: int = 0,
                 rules: MeshRules = FSDP_TP) -> tuple[Any, Any]:
    """Initialise params + opt state directly sharded on ``mesh``."""
    p_spec = jax.eval_shape(lambda: init_params(cfg, jax.random.key(seed)))
    p_sh = params_shardings(p_spec, mesh, rules)
    params = jax.jit(lambda: init_params(cfg, jax.random.key(seed)),
                     out_shardings=p_sh)()
    o_spec = jax.eval_shape(lambda: init_opt_state(params))
    o_sh = {"master": params_shardings(o_spec["master"], mesh, rules),
            "m": params_shardings(o_spec["m"], mesh, rules),
            "v": params_shardings(o_spec["v"], mesh, rules),
            "step": NamedSharding(mesh, P())}
    opt_state = jax.jit(lambda p: init_opt_state(p), out_shardings=o_sh)(params)
    return params, opt_state


# ---------------------------------------------------------------------------
# shard_map DP path with gradient compression (hierarchical pod reduce)
# ---------------------------------------------------------------------------

def make_dp_train_step(cfg: ModelConfig, mesh: Mesh,
                       train: TrainConfig = TrainConfig()) -> Callable:
    """Explicit-DP step: params replicated, batch sharded over data axes,
    grads compressed (error feedback) then mean-reduced per axis level.

    step(params, opt_state, err_state, batch)
        -> (params, opt_state, err_state, metrics)
    """
    axes = batch_axes(mesh)

    def inner(params, opt_state, err, batch):
        loss, grads = loss_and_grads(cfg, params, batch, train.microbatches)
        # hierarchical: intra-pod ("data") first, then cross-pod ("pod")
        for ax in reversed(axes):
            grads, err = compressed_mean(grads, err, ax, train.compression)
        loss = jax.lax.pmean(loss, axes[0])
        if len(axes) > 1:
            loss = jax.lax.pmean(loss, axes[1])
        new_params, new_state = adamw_update(train.opt, params, grads,
                                             opt_state)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "step": new_state["step"]}
        return new_params, new_state, err, metrics

    rep = P()
    bspec = P(axes)

    def batch_specs(batch):
        return jax.tree.map(lambda _: bspec, batch)

    def step(params, opt_state, err, batch):
        fn = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params),
                      jax.tree.map(lambda _: rep, opt_state),
                      jax.tree.map(lambda _: rep, err),
                      batch_specs(batch)),
            out_specs=(jax.tree.map(lambda _: rep, params),
                       jax.tree.map(lambda _: rep, opt_state),
                       jax.tree.map(lambda _: rep, err),
                       {"loss": rep, "grad_norm": rep, "step": rep}),
            check_vma=False)
        return jax.jit(fn, donate_argnums=(0, 1, 2))(
            params, opt_state, err, batch)

    return step


def init_dp_error_state(params: Any) -> Any:
    return init_error_state(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
