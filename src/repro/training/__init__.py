"""Training substrate: optimizer, data pipeline, train step, checkpointing."""
