"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step) — restart-safe: resuming from
a checkpoint at step k regenerates exactly the batches k, k+1, … with no
data-order state to persist.  Batches are placed sharded (batch dim over the
data axes) straight onto the mesh, so host memory never holds more than its
own shard on multi-host runs (here: single host, full array).

The token stream is a mixture of Zipf-distributed ids (realistic rank-
frequency mass for LM loss curves) plus a deterministic structural pattern
(a repeating n-gram per sequence) that gives the model something learnable —
loss decreasing over a few hundred steps is a real signal, which the
quickstart example and integration tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import batch_shardings


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 8          # length of the learnable repeating pattern


def _host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    # zipf body (clipped to vocab), then overwrite a periodic n-gram
    z = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq + 1)) - 1
    toks = np.minimum(z, cfg.vocab - 1).astype(np.int32)
    grams = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.ngram),
                         dtype=np.int32)
    reps = -(-(cfg.seq + 1) // cfg.ngram)
    pattern = np.tile(grams, (1, reps))[:, :cfg.seq + 1]
    mask = rng.random((cfg.batch, cfg.seq + 1)) < 0.75
    toks = np.where(mask, pattern, toks)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch(cfg: DataConfig, step: int, mesh: Mesh | None = None,
               extras: dict[str, Any] | None = None) -> dict[str, Any]:
    """Batch for ``step``: {tokens, labels} (+ stub frontend inputs)."""
    host = _host_batch(cfg, step)
    batch: dict[str, Any] = {k: jnp.asarray(v) for k, v in host.items()}
    if extras:
        # stub modality frontends: deterministic pseudo-embeddings
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        for name, shape in extras.items():
            batch[name] = (jax.random.normal(
                jax.random.fold_in(key, hash(name) % (2**31)), shape,
                jnp.float32) * 0.02).astype(jnp.bfloat16)
    if mesh is not None:
        sh = batch_shardings(batch, mesh)
        batch = jax.tree.map(jax.device_put, batch, sh)
    return batch


def batch_iterator(cfg: DataConfig, mesh: Mesh | None = None,
                   start_step: int = 0,
                   extras: dict[str, Any] | None = None):
    step = start_step
    while True:
        yield step, make_batch(cfg, step, mesh, extras)
        step += 1
