"""AdamW with f32 master weights + cosine LR schedule (pure JAX).

bf16 training keeps a f32 master copy of every parameter inside the
optimizer state; the model's bf16 params are re-cast from the masters after
each update (the standard mixed-precision recipe).  The optimizer state is
a pytree mirroring the params, so the same sharding rules apply leaf-wise.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to ``min_lr_frac·lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict[str, Any]:
    """master (f32 copy), m, v, step."""
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"master": master, "m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (standard practice)."""
    names = [str(getattr(k, "key", k)) for k in path]
    leaf = names[-1]
    return leaf not in ("scale", "bias", "ba", "bx", "bq", "bk", "bv",
                        "lambda", "A_log", "D", "dt_bias")


def adamw_update(cfg: OptConfig, params: Any, grads: Any,
                 state: dict[str, Any]) -> tuple[Any, dict[str, Any]]:
    """One AdamW step.  Returns (new bf16/bf-dtype params, new state)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p_master, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p_master
        return p_master - lr * delta, m2, v2

    triples = jax.tree_util.tree_map_with_path(
        upd, state["master"], grads, state["m"], state["v"])
    new_master = jax.tree.map(lambda t: t[0], triples,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], triples,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], triples,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype),
                              new_master, params)
    return new_params, {"master": new_master, "m": new_m, "v": new_v,
                        "step": step}
